"""Qwen2.5-32B [hf:Qwen/Qwen2.5-*]: dense, GQA kv=8, QKV bias."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, head_pad_multiple=16, rope_theta=1_000_000.0, act="silu", norm_eps=1e-6,
))
