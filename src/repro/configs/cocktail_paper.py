"""The paper's own testbed scale (Sec. IV-A): 6 CUs, 3 ECs, LSTM-class
traffic model. Used by the fig7 benchmark and the traffic example."""
from repro.core import CocktailConfig

TESTBED = CocktailConfig(
    n_cu=6, n_ec=3, delta=0.02, eps=0.1, rho=1.0, q0=5000.0, zeta=500.0,
    d_base=2000.0, cap_d_base=8000.0, f_base=(8000.0, 20000.0, 8000.0),
    c_base=250.0, e_base=50.0, p_base=200.0, seed=0,
)
