"""PaliGemma-3B [arXiv:2407.07726]: SigLIP frontend STUBBED (precomputed
patch embeddings) + gemma decoder with bidirectional image prefix."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, n_img_tokens=256,
    scale_embed=True, act="gelu", norm_eps=1e-6, tie_embeddings=True,
))
