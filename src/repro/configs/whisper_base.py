"""Whisper-base [arXiv:2212.04356]: enc-dec; conv frontend STUBBED — the
encoder consumes precomputed frame embeddings per the assignment."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865, enc_ctx=1500,
    act="gelu", norm_eps=1e-5, tie_embeddings=True,
))
