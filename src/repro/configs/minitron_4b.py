"""Minitron-4B [arXiv:2407.14679]: pruned Nemotron; squared-ReLU MLP."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256000,
    head_pad_multiple=16, rope_theta=10000.0, act="relu2", norm_eps=1e-5,
))
