"""Architecture config system.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``
with the exact published numbers; ``reduced()`` derives the CPU smoke-test
variant of the same family. ``register``/``get_config`` back the ``--arch``
selector used by the launchers, and ``SHAPES`` defines the assigned
input-shape grid (shared by all LM-family archs).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_REGISTRY: dict[str, "ArchConfig"] = {}

# Assigned input shapes: name -> (seq_len, global_batch, kind)
SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k requires sub-quadratic attention (see DESIGN.md §4).
LONG_CONTEXT_OK = {"gemma2-27b", "mixtral-8x22b", "mixtral-8x7b",
                   "zamba2-2.7b", "falcon-mamba-7b"}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # TP alignment: pad Q heads up to a multiple of this so the head dim
    # shards exactly on the 16-way model axis (padding rows of wo are
    # zero-initialised -> identical function, documented FLOP overhead).
    # 1 = never pad (small archs whose attention is cheaper replicated).
    head_pad_multiple: int = 1
    # attention features
    qkv_bias: bool = False
    logit_softcap: float = 0.0  # gemma2 final-logit soft cap
    attn_softcap: float = 0.0  # gemma2 attention-logit soft cap
    sliding_window: int = 0  # >0: all attn layers windowed (mixtral SWA)
    local_global_alternate: bool = False  # gemma2: alternate local/global
    post_norm: bool = False  # gemma2 post-block RMSNorm
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # mamba2 head dim
    ssm_version: int = 1
    ssm_chunk: int = 256
    dt_rank: int = 0  # mamba1 low-rank dt; 0 -> ceil(d_model/16)
    # hybrid (zamba2): one *shared* attention block applied every k-th layer
    hybrid_attn_every: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_ctx: int = 0  # audio frames after the (stubbed) conv frontend
    # vlm (paligemma)
    n_img_tokens: int = 0
    # misc
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu | relu2
    scale_embed: bool = False  # gemma family: x *= sqrt(d_model)
    tie_embeddings: bool = False
    remat: bool = True
    # Dry-run cost extrapolation: XLA's cost_analysis counts a while-loop
    # body ONCE; the dry-run compiles small unrolled variants to recover
    # exact per-layer costs (see launch/dryrun.py).
    unroll_layers: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_heads(self) -> int:
        """Q-head count after TP padding (>= n_heads, multiple of both the
        pad multiple and the kv group size)."""
        m = max(self.head_pad_multiple, 1)
        h = -(-self.n_heads // m) * m
        if self.n_kv_heads > 0:
            while h % self.n_kv_heads:
                h += 1
        return h

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def n_params(self) -> int:
        """Approximate total parameter count (used for roofline MODEL_FLOPS)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + self.n_heads * hd * d
        if self.act in ("silu", "gelu"):
            mlp = 3 * d * ff  # gated
        else:
            mlp = 2 * d * ff
        if self.family == "moe":
            mlp = mlp * self.n_experts + d * self.n_experts
        per_layer = attn + mlp
        if self.family == "ssm":
            di, n = self.d_inner, self.ssm_state
            per_layer = 2 * d * di + di * self.ssm_conv + \
                di * (self.resolved_dt_rank + 2 * n) + self.resolved_dt_rank * di + di * d
        if self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            heads = di // self.ssm_head_dim
            per_layer = d * (2 * di + 2 * n + heads) + di * self.ssm_conv + di * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = L * per_layer + emb
        if self.family == "hybrid" and self.hybrid_attn_every:
            hd_ = self.resolved_head_dim
            total += (self.d_model * hd_ * self.n_heads + 2 * self.d_model * hd_ * self.n_kv_heads
                      + self.n_heads * hd_ * self.d_model + 3 * self.d_model * self.d_ff)
        if self.family == "encdec":
            total += self.n_enc_layers * (2 * attn + mlp)  # enc self-attn + dec cross-attn
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (= n_params for non-MoE)."""
        if self.family != "moe":
            return self.n_params()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + self.n_heads * hd * d
        mlp = 3 * d * ff * self.n_experts_per_tok + d * self.n_experts
        return int(L * (attn + mlp) + self.vocab_size * d * 2)

    def shapes(self) -> dict[str, tuple[int, int, str]]:
        """The assigned (shape-name -> spec) cells for this arch, with the
        DESIGN.md §4 applicability rules applied."""
        out = dict(SHAPES)
        if self.name not in LONG_CONTEXT_OK and "long_500k" in out:
            del out["long_500k"]
        return out


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


ARCH_IDS = [
    "qwen2_5_32b", "minitron_4b", "granite_20b", "gemma2_27b",
    "mixtral_8x22b", "mixtral_8x7b", "zamba2_2_7b", "whisper_base",
    "falcon_mamba_7b", "paligemma_3b",
]


def get_config(name: str) -> ArchConfig:
    """Look up an architecture by its public id (e.g. 'qwen2.5-32b')."""
    key = name.replace(".", "_").replace("-", "_")
    if not _REGISTRY:
        for mod in ARCH_IDS:
            importlib.import_module(f"repro.configs.{mod}")
    for cfg in _REGISTRY.values():
        if cfg.name == name or cfg.name.replace(".", "_").replace("-", "_") == key:
            return cfg
    raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")


def all_configs() -> dict[str, ArchConfig]:
    if not _REGISTRY:
        for mod in ARCH_IDS:
            importlib.import_module(f"repro.configs.{mod}")
    return dict(_REGISTRY)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test-size variant of the same family (CPU, one forward/step)."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 4 if cfg.family in ("hybrid",) else 2),
        d_model=64,
        n_heads=4,
        head_pad_multiple=1,
        n_kv_heads=min(max(cfg.n_kv_heads, 1), 2),
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        n_experts=min(cfg.n_experts, 4),
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_head_dim=16,
        dt_rank=8 if cfg.family == "ssm" else 0,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        hybrid_attn_every=min(cfg.hybrid_attn_every, 2) if cfg.hybrid_attn_every else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_ctx=min(cfg.enc_ctx, 16) if cfg.enc_ctx else 0,
        n_img_tokens=min(cfg.n_img_tokens, 4) if cfg.n_img_tokens else 0,
        remat=False,
        param_dtype="float32",
        compute_dtype="float32",
    )
