"""Falcon-Mamba-7B [arXiv:2410.05355]: pure Mamba-1, attention-free."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=65024,
    ssm_state=16, ssm_version=1, ssm_conv=4, ssm_expand=2,
    act="silu", norm_eps=1e-5, tie_embeddings=True,
))
