"""Architecture configs (assigned pool + the paper's own testbed)."""
from .base import (ARCH_IDS, LONG_CONTEXT_OK, SHAPES, ArchConfig, all_configs,
                   get_config, reduced, register)

__all__ = ["ARCH_IDS", "ArchConfig", "LONG_CONTEXT_OK", "SHAPES",
           "all_configs", "get_config", "reduced", "register"]
