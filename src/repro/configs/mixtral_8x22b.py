"""Mixtral-8x22B [arXiv:2401.04088]: 8-expert top-2 MoE, SWA."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    head_pad_multiple=16, n_experts=8, n_experts_per_tok=2, sliding_window=4096,
    rope_theta=1_000_000.0, act="silu", norm_eps=1e-5,
))
