"""Mixtral-8x7B [arXiv:2401.04088]: 8-expert top-2 MoE, SWA."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    head_pad_multiple=16, n_experts=8, n_experts_per_tok=2, sliding_window=4096,
    rope_theta=1_000_000.0, act="silu", norm_eps=1e-5,
))
