"""Granite-20B-code [arXiv:2405.04324]: llama-arch, MQA (kv=1)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    head_pad_multiple=16, rope_theta=10000.0, act="gelu", norm_eps=1e-5,
))
