"""Gemma2-27B [arXiv:2408.00118]: alternating local/global attention,
attention + final logit soft-capping, post-block norms."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    head_pad_multiple=16, local_global_alternate=True, sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0, post_norm=True,
    scale_embed=True, act="gelu", norm_eps=1e-6, tie_embeddings=True,
))
