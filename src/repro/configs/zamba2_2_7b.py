"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone + shared attention block
applied periodically (hybrid)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_version=2, ssm_head_dim=64, ssm_conv=4, ssm_expand=2,
    head_pad_multiple=16, hybrid_attn_every=6, act="gelu", norm_eps=1e-5,
))
