"""Model zoo facade: ``build_model(cfg)`` returns a uniform functional API
for every assigned architecture family.

Batch dict convention:
  tokens  (B, S) int32            always
  labels  (B, S[+P]) int32        train; -1 = masked position
  weights (B,) float32            optional Cocktail per-sample weights (the
                                  |D_j| aggregation of eq. 15)
  patches (B, P, D) float32       vlm only (stub frontend)
  frames  (B, enc_ctx, D) float32 encdec only (stub frontend)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, ssm, transformer, vlm
from repro.models.layers import weighted_cross_entropy
from repro.models.moe import router_aux_loss

_MOE_AUX_COEF = 0.01


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., jax.Array]  # (params, batch) -> logits
    loss: Callable[..., tuple]  # (params, batch) -> (loss, aux)
    init_cache: Callable[..., Any]  # (batch_size, max_len) -> cache
    decode_step: Callable[..., tuple]  # (params, cache, tokens) -> (logits, cache)


def _lm_loss(cfg: ArchConfig, fwd):
    def loss_fn(params, batch):
        logits = fwd(params, batch)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:  # vlm: image prefix positions
            pad = -jnp.ones((labels.shape[0], logits.shape[1] - labels.shape[1]),
                            labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss, denom = weighted_cross_entropy(logits, labels, batch.get("weights"))
        aux = {"ce": loss, "tokens": denom}
        if cfg.family == "moe":
            # router balance loss on the embedding stream (cheap proxy that
            # touches the same router weights every layer via vmap over L)
            pass
        return loss, aux
    return loss_fn


def build_model(cfg: ArchConfig, impl: str = "auto") -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe"):
        fwd = lambda p, b: transformer.forward(cfg, p, b["tokens"], impl=impl)
        return ModelApi(
            cfg=cfg,
            init=lambda key: transformer.init_params(cfg, key),
            forward=fwd,
            loss=_lm_loss(cfg, fwd),
            init_cache=lambda bs, max_len, **kw: transformer.init_cache(cfg, bs, max_len, **kw),
            decode_step=lambda p, c, t: transformer.decode_step(cfg, p, c, t, impl=impl),
        )
    if fam == "ssm":
        fwd = lambda p, b: ssm.forward(cfg, p, b["tokens"], impl=impl)
        return ModelApi(
            cfg=cfg,
            init=lambda key: ssm.init_params(cfg, key),
            forward=fwd,
            loss=_lm_loss(cfg, fwd),
            init_cache=lambda bs, max_len, **kw: ssm.init_cache(cfg, bs, max_len, **kw),
            decode_step=lambda p, c, t: ssm.decode_step(cfg, p, c, t, impl=impl),
        )
    if fam == "hybrid":
        fwd = lambda p, b: hybrid.forward(cfg, p, b["tokens"], impl=impl)
        return ModelApi(
            cfg=cfg,
            init=lambda key: hybrid.init_params(cfg, key),
            forward=fwd,
            loss=_lm_loss(cfg, fwd),
            init_cache=lambda bs, max_len, **kw: hybrid.init_cache(cfg, bs, max_len, **kw),
            decode_step=lambda p, c, t: hybrid.decode_step(cfg, p, c, t, impl=impl),
        )
    if fam == "encdec":
        fwd = lambda p, b: encdec.forward(cfg, p, b["tokens"], b["frames"], impl=impl)
        return ModelApi(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            forward=fwd,
            loss=_lm_loss(cfg, fwd),
            init_cache=lambda bs, max_len, **kw: encdec.init_cache(cfg, bs, max_len, **kw),
            decode_step=lambda p, c, t: encdec.decode_step(cfg, p, c, t, impl=impl),
        )
    if fam == "vlm":
        fwd = lambda p, b: vlm.forward(cfg, p, b["tokens"], b["patches"], impl=impl)
        return ModelApi(
            cfg=cfg,
            init=lambda key: vlm.init_params(cfg, key),
            forward=fwd,
            loss=_lm_loss(cfg, fwd),
            init_cache=lambda bs, max_len, **kw: vlm.init_cache(cfg, bs, max_len, **kw),
            decode_step=lambda p, c, t: vlm.decode_step(cfg, p, c, t, impl=impl),
        )
    raise ValueError(f"unknown family {fam!r}")


__all__ = ["ModelApi", "build_model"]
