"""Mamba-1 / Mamba-2 blocks and the pure-SSM LM (falcon-mamba).

Decode is O(1) per token (conv tail + recurrent state), which is why the
ssm/hybrid archs run the long_500k cell (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels.mamba_scan.ops import mamba1_scan, mamba2_scan
from repro.models import layers as L
from repro.parallel.sharding import constrain_act, gather_fsdp


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_mamba1_stack(cfg: ArchConfig, key, n_layers: int) -> dict:
    d, di, n, r, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.resolved_dt_rank, cfg.ssm_conv)
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)

    def dense(kk, shape, in_axis=0, scale=1.0):
        flat = jax.random.normal(kk, (n_layers,) + shape, jnp.float32)
        return (flat * scale / np.sqrt(shape[in_axis])).astype(dt)

    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                      (n_layers, di, n)))
    return {
        "norm": jnp.zeros((n_layers, d), dt),
        "in_proj": dense(ks[0], (d, 2 * di)),
        "conv_w": (jax.random.normal(ks[1], (n_layers, di, k), jnp.float32) / np.sqrt(k)).astype(dt),
        "conv_b": jnp.zeros((n_layers, di), dt),
        "x_proj": dense(ks[2], (di, r + 2 * n)),
        "dt_proj": dense(ks[3], (r, di), scale=r ** 0.5 * 0.1),
        "dt_bias": jnp.log(jnp.exp(jnp.full((n_layers, di), 0.01)) - 1.0).astype(dt),
        "a_log": a_init.astype(dt),
        "ssm_d": jnp.ones((n_layers, di), dt),
        "out_proj": dense(ks[4], (di, d), scale=1.0 / np.sqrt(2 * cfg.n_layers) * np.sqrt(di)),
    }


def init_mamba2_stack(cfg: ArchConfig, key, n_layers: int) -> dict:
    d, di, n, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    heads = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)

    def dense(kk, shape, in_axis=0, scale=1.0):
        flat = jax.random.normal(kk, (n_layers,) + shape, jnp.float32)
        return (flat * scale / np.sqrt(shape[in_axis])).astype(dt)

    return {
        "norm": jnp.zeros((n_layers, d), dt),
        # [z | x | B | C | dt] fused input projection (mamba2 layout)
        "in_proj": dense(ks[0], (d, 2 * di + 2 * n + heads)),
        "conv_w": (jax.random.normal(ks[1], (n_layers, di, k), jnp.float32) / np.sqrt(k)).astype(dt),
        "conv_b": jnp.zeros((n_layers, di), dt),
        "dt_bias": jnp.log(jnp.exp(jnp.full((n_layers, heads), 0.01)) - 1.0).astype(dt),
        "a_log": jnp.zeros((n_layers, heads), dt),
        "ssm_d": jnp.ones((n_layers, heads), dt),
        "gate_norm": jnp.zeros((n_layers, di), dt),
        "out_proj": dense(ks[2], (di, d), scale=1.0 / np.sqrt(2 * cfg.n_layers) * np.sqrt(di)),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv (+ stateful tail for decode)
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                state: Optional[jax.Array] = None):
    """x (B, S, DI), w (DI, K), b (DI,). Returns (y, new_state) where state
    holds the last K-1 inputs for streaming decode."""
    bsz, s, di = x.shape
    k = w.shape[1]
    if state is None:
        pad = jnp.zeros((bsz, k - 1, di), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, DI)
    # depthwise: sum_k x[t - K + 1 + k] * w[:, k]
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xp[:, i:i + s, :] * w[None, None, :, i].reshape(1, 1, di)
    new_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros((bsz, 0, di), x.dtype)
    return y + b[None, None], new_state


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def mamba1_block(cfg: ArchConfig, x, p, state=None, impl: str = "auto"):
    """x (B, S, D). state: None (train) or dict(conv, h) for decode.
    Returns (out, new_state)."""
    r, n = cfg.resolved_dt_rank, cfg.ssm_state
    di = cfg.d_inner
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, gather_fsdp(p["in_proj"], (None, "model")))
    xz = constrain_act(xz, ("batch", None, "model"))
    xi, z = jnp.split(xz, [di], axis=-1)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)
    proj = jnp.einsum("bse,ef->bsf", xi, p["x_proj"])
    dt_r, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"])
                         + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    h0 = None if state is None else state["h"]
    y, h_new = mamba1_scan(xi, dt, a, bmat, cmat, h0=h0, chunk=cfg.ssm_chunk,
                           impl=impl)
    y = y + xi * p["ssm_d"][None, None]
    y = y * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", y, gather_fsdp(p["out_proj"], ("model", None)))
    new_state = None if state is None else {"conv": new_conv, "h": h_new}
    return constrain_act(out, ("batch", "seq", None)), new_state


def mamba2_block(cfg: ArchConfig, x, p, state=None, impl: str = "auto"):
    """Mamba-2 (SSD) block; heads = d_inner / ssm_head_dim, shared B/C."""
    di, n = cfg.d_inner, cfg.ssm_state
    heads = di // cfg.ssm_head_dim
    ph = cfg.ssm_head_dim
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, gather_fsdp(p["in_proj"], (None, "model")))
    z, xi, bmat, cmat, dt_in = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)
    dt = jax.nn.softplus(dt_in + p["dt_bias"][None, None])  # (B, S, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    bsz, s = xi.shape[:2]
    xh = xi.reshape(bsz, s, heads, ph)
    h0 = None if state is None else state["h"]
    y, h_new = mamba2_scan(xh, dt, a, bmat, cmat, h0=h0, chunk=cfg.ssm_chunk,
                           impl=impl)
    y = y + xh * p["ssm_d"][None, None, :, None]  # per-head skip (D term)
    y = y.reshape(bsz, s, di)
    # gated RMSNorm (mamba2): norm(y) * silu(z)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, gather_fsdp(p["out_proj"], ("model", None)))
    new_state = None if state is None else {"conv": new_conv, "h": h_new}
    return constrain_act(out, ("batch", "seq", None)), new_state


# ---------------------------------------------------------------------------
# Pure-SSM LM (falcon-mamba)
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key) -> dict:
    k_emb, k_blocks = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    params = {
        "embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
        "blocks": init_mamba1_stack(cfg, k_blocks, cfg.n_layers),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(key, (cfg.d_model, cfg.vocab_size), dtype=dt)
    return params


def forward(cfg: ArchConfig, params, tokens, impl: str = "auto"):
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    x = gather_fsdp(cparams["embed"], ("model", None))[tokens].astype(cdt)
    x = constrain_act(x, ("batch", None, None))

    def body(xx, layer_p):
        out, _ = mamba1_block(cfg, xx, layer_p, impl=impl)
        return out, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = L.scan_layers(cfg, body_fn, x, cparams["blocks"])
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = gather_fsdp(cparams["embed"], ("model", None)).T
    else:
        head = gather_fsdp(cparams["head"], (None, "model"))
    return jnp.einsum("bsd,dv->bsv", x, head)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "pos": jnp.zeros((), jnp.int32),
        "conv": jnp.zeros((cfg.n_layers, batch, k - 1, di), dt),
        "h": jnp.zeros((cfg.n_layers, batch, di, n), jnp.float32),
    }


def decode_step(cfg: ArchConfig, params, cache: dict, tokens, impl: str = "auto"):
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    x = gather_fsdp(cparams["embed"], ("model", None))[tokens].astype(cdt)

    def body(xx, scanned):
        out, new_state = mamba1_block(
            cfg, xx, scanned["p"],
            state={"conv": scanned["conv"], "h": scanned["h"]}, impl=impl)
        return out, new_state

    x, new_states = L.scan_layers(
        cfg, body, x, {"p": cparams["blocks"], "conv": cache["conv"], "h": cache["h"]})
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = gather_fsdp(cparams["embed"], ("model", None)).T
    else:
        head = gather_fsdp(cparams["head"], (None, "model"))
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, {"pos": cache["pos"] + 1, "conv": new_states["conv"],
                    "h": new_states["h"]}
