"""Decoder-only transformer LM (dense + MoE) with scan-over-layers.

Covers: qwen2.5 (GQA + QKV bias), minitron (relu^2 MLP), granite (MQA),
gemma2 (alternating local/global attention, logit soft-caps, post-norms,
embed scaling, tied head), mixtral (top-2 MoE + SWA), and the PaliGemma
text backbone (prefix-LM mask over stubbed patch embeddings).

Design notes:
  * All per-layer params are stacked on a leading L dim and consumed by
    ``lax.scan`` -> O(1-layer) HLO, essential for CPU compile of 64L models.
  * Alternating local/global archs scan over *pairs* of layers so the
    sliding-window spec stays static inside the traced body.
  * Attention projections keep heads as an explicit dim (D, H, hd) so tensor
    parallelism never reshapes across a sharded dimension.
  * Decode uses ring-buffer KV caches for windowed layers (W slots) and full
    caches for global layers; `kv_pos` tracks absolute positions so masks
    stay correct after wrap-around.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import AttnSpec
from repro.models import layers as L
from repro.models.moe import init_moe_params, moe_ffn
from repro.parallel.sharding import constrain_act, gather_fsdp, kv_layout


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block_stack(cfg: ArchConfig, key, n_layers: int) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    h, hkv, hd = cfg.padded_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 16)
    dt = jnp.dtype(cfg.param_dtype)
    out_scale = 1.0 / np.sqrt(2 * cfg.n_layers)

    def dense(k, shape, in_axis=0, scale=1.0):
        flat = jax.random.normal(k, (n_layers,) + shape, jnp.float32)
        return (flat * scale / np.sqrt(shape[in_axis])).astype(dt)

    wo = dense(ks[3], (h, hd, d), in_axis=0, scale=out_scale * np.sqrt(hd))
    if h > cfg.n_heads:  # TP padding: extra heads never contribute
        wo = wo.at[:, cfg.n_heads:].set(0.0)
    p = {
        "attn_norm": jnp.zeros((n_layers, d), dt),
        "wq": dense(ks[0], (d, h, hd)),
        "wk": dense(ks[1], (d, hkv, hd)),
        "wv": dense(ks[2], (d, hkv, hd)),
        "wo": wo,
        "mlp_norm": jnp.zeros((n_layers, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, h, hd), dt)
        p["bk"] = jnp.zeros((n_layers, hkv, hd), dt)
        p["bv"] = jnp.zeros((n_layers, hkv, hd), dt)
    if cfg.post_norm:
        p["attn_post_norm"] = jnp.zeros((n_layers, d), dt)
        p["mlp_post_norm"] = jnp.zeros((n_layers, d), dt)
    if cfg.family == "moe":
        p.update(init_moe_params(cfg, ks[4], n_layers))
    else:
        if cfg.act in ("silu", "gelu"):
            p["w_gate"] = dense(ks[5], (d, ff))
        p["w_up"] = dense(ks[6], (d, ff))
        p["w_down"] = dense(ks[7], (ff, d), in_axis=0, scale=out_scale * np.sqrt(ff))
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    params = {
        "embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
        "blocks": _init_block_stack(cfg, k_blocks, cfg.n_layers),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dt)
    return params


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ArchConfig, prefix_len: int = 0) -> list[AttnSpec]:
    """Static per-sublayer attention specs; len == layers consumed per scan
    step (2 for alternating local/global, else 1)."""
    base = dict(causal=True, softcap=cfg.attn_softcap, prefix_len=prefix_len)
    if cfg.local_global_alternate:
        return [AttnSpec(window=cfg.sliding_window, **base), AttnSpec(window=0, **base)]
    return [AttnSpec(window=cfg.sliding_window, **base)]


def _project_qkv(cfg, x, p, positions):
    q = jnp.einsum("bsd,dhf->bshf", x, gather_fsdp(p["wq"], (None, "model", None)))
    k = jnp.einsum("bsd,dhf->bshf", x, gather_fsdp(p["wk"], (None, "model", None)))
    v = jnp.einsum("bsd,dhf->bshf", x, gather_fsdp(p["wv"], (None, "model", None)))
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(cfg, x, p):
    if cfg.family == "moe":
        return moe_ffn(cfg, x, p)
    if cfg.act in ("silu", "gelu"):
        h = L.activate(jnp.einsum("bsd,df->bsf", x, gather_fsdp(p["w_gate"], (None, "model"))), cfg.act)
        h = h * jnp.einsum("bsd,df->bsf", x, gather_fsdp(p["w_up"], (None, "model")))
    else:
        h = L.activate(jnp.einsum("bsd,df->bsf", x, gather_fsdp(p["w_up"], (None, "model"))), cfg.act)
    h = constrain_act(h, ("batch", None, "model"))
    return jnp.einsum("bsf,fd->bsd", h, gather_fsdp(p["w_down"], ("model", None)))


def block_apply(cfg: ArchConfig, x, p, positions, spec: AttnSpec,
                kv_override=None, impl: str = "auto"):
    """One transformer block. kv_override=(k, v, kv_pos, kv_valid) lets the
    decode path inject cache contents."""
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, h, p, positions)
    q = constrain_act(q, ("batch", None, "model", None))
    if kv_override is not None:
        k, v, kv_pos, kv_valid = kv_override
    else:
        kv_pos, kv_valid = positions, None
    attn = flash_attention(q, k, v, positions, kv_pos, spec,
                           kv_valid=kv_valid, impl=impl)
    attn = jnp.einsum("bshf,hfd->bsd", attn, gather_fsdp(p["wo"], ("model", None, None)))
    if cfg.post_norm:
        attn = L.rms_norm(attn, p["attn_post_norm"], cfg.norm_eps)
    x = x + attn
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    ff = _ffn(cfg, h, p)
    if cfg.post_norm:
        ff = L.rms_norm(ff, p["mlp_post_norm"], cfg.norm_eps)
    x = x + ff
    return constrain_act(x, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(cfg: ArchConfig, params, tokens, extra_embeds=None):
    x = gather_fsdp(params["embed"], ("model", None))[tokens].astype(
        jnp.dtype(cfg.compute_dtype))
    if extra_embeds is not None:  # vlm: prepend patch embeddings
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    if cfg.scale_embed:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    return x


def _stack_pairs(tree, group: int):
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] // group, group) + a.shape[1:]), tree)


def forward(cfg: ArchConfig, params, tokens, extra_embeds=None,
            prefix_len: int = 0, impl: str = "auto"):
    """tokens (B, S_text) -> logits (B, S_total, V). extra_embeds (B, P, D)
    are prepended (PaliGemma patches); prefix_len marks bidirectional kv."""
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    x = _embed(cfg, cparams, tokens, extra_embeds)
    x = constrain_act(x, ("batch", None, None))
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    specs = _attn_specs(cfg, prefix_len)
    group = len(specs)
    blocks = _stack_pairs(cparams["blocks"], group) if group > 1 else cparams["blocks"]

    def body(carry, layer_p):
        xx = carry
        for i, spec in enumerate(specs):
            lp = jax.tree.map(lambda a: a[i], layer_p) if group > 1 else layer_p
            xx = block_apply(cfg, xx, lp, positions, spec, impl=impl)
        return xx, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = L.scan_layers(cfg, body_fn, x, blocks)
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = gather_fsdp(cparams["embed"], ("model", None)).T
    else:
        head = gather_fsdp(cparams["head"], (None, "model"))
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.logit_softcap > 0:
        logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return constrain_act(logits, ("batch", None, "model"))


# ---------------------------------------------------------------------------
# Decode (KV cache, one token per call)
# ---------------------------------------------------------------------------

def _cache_len(cfg: ArchConfig, spec: AttnSpec, max_len: int) -> int:
    return min(max_len, spec.window) if spec.window > 0 else max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    """KV cache pytree. Per spec-group stacks: windowed layers get ring
    buffers of W slots, global layers full max_len buffers."""
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    specs = _attn_specs(cfg)
    group = len(specs)
    n = cfg.n_layers // group
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    for i, spec in enumerate(specs):
        slen = _cache_len(cfg, spec, max_len)
        cache[f"k{i}"] = jnp.zeros((n, batch, slen, hkv, hd), dt)
        cache[f"v{i}"] = jnp.zeros((n, batch, slen, hkv, hd), dt)
        cache[f"kv_pos{i}"] = jnp.full((n, batch, slen), -1, jnp.int32)
    return cache


def decode_step(cfg: ArchConfig, params, cache: dict, tokens, impl: str = "auto"):
    """tokens (B, 1) -> (logits (B, 1, V), updated cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    x = _embed(cfg, cparams, tokens)
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    specs = _attn_specs(cfg)
    group = len(specs)
    blocks = _stack_pairs(cparams["blocks"], group) if group > 1 else cparams["blocks"]

    def body(xx, scanned):
        layer_p = scanned["p"]
        new_kv = {}
        for i, spec in enumerate(specs):
            lp = jax.tree.map(lambda a: a[i], layer_p) if group > 1 else layer_p
            kc, vc, pc = scanned[f"k{i}"], scanned[f"v{i}"], scanned[f"kv_pos{i}"]
            slot = pos % kc.shape[1] if spec.window > 0 else jnp.minimum(pos, kc.shape[1] - 1)
            h = L.rms_norm(xx, lp["attn_norm"], cfg.norm_eps)
            q, k_new, v_new = _project_qkv(cfg, h, lp, positions)
            if kv_layout(cfg.n_kv_heads) == "seq":
                # seq-sharded cache: replicate q heads so the attention
                # contraction stays local per seq shard (see specs.py)
                q = constrain_act(q, ("batch", None, None, None))
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k_new.astype(kc.dtype), slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v_new.astype(vc.dtype), slot, axis=1)
            pc = jax.lax.dynamic_update_slice_in_dim(
                pc, jnp.full((b, 1), pos, jnp.int32), slot, axis=1)
            attn = flash_attention(q, kc, vc, positions, pc, spec,
                                   kv_valid=pc >= 0, impl=impl)
            attn = jnp.einsum("bshf,hfd->bsd", attn, gather_fsdp(lp["wo"], ("model", None, None)))
            if cfg.post_norm:
                attn = L.rms_norm(attn, lp["attn_post_norm"], cfg.norm_eps)
            xx = xx + attn
            h = L.rms_norm(xx, lp["mlp_norm"], cfg.norm_eps)
            ff = _ffn(cfg, h, lp)
            if cfg.post_norm:
                ff = L.rms_norm(ff, lp["mlp_post_norm"], cfg.norm_eps)
            xx = xx + ff
            new_kv[f"k{i}"], new_kv[f"v{i}"], new_kv[f"kv_pos{i}"] = kc, vc, pc
        return xx, new_kv

    scanned = {"p": blocks}
    for i in range(group):
        for key in (f"k{i}", f"v{i}", f"kv_pos{i}"):
            scanned[key] = cache[key]
    x, new_kv = L.scan_layers(cfg, body, x, scanned)
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = gather_fsdp(cparams["embed"], ("model", None)).T
    else:
        head = gather_fsdp(cparams["head"], (None, "model"))
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.logit_softcap > 0:
        logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    new_cache = dict(cache)
    new_cache.update(new_kv)
    new_cache["pos"] = pos + 1
    return logits, new_cache
