"""PaliGemma-style VLM: stubbed SigLIP patch embeddings + gemma decoder.

Per the assignment the vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_img_tokens, D) which are prepended to the
text embeddings with a bidirectional prefix mask (prefix-LM), exactly the
PaliGemma training setup for the text backbone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer


def init_params(cfg: ArchConfig, key) -> dict:
    return transformer.init_params(cfg, key)


def forward(cfg: ArchConfig, params, tokens, patches, impl: str = "auto"):
    """tokens (B, S_text), patches (B, P, D) -> logits (B, P + S_text, V)."""
    return transformer.forward(cfg, params, tokens, extra_embeds=patches,
                               prefix_len=cfg.n_img_tokens, impl=impl)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    return transformer.init_cache(cfg, batch, max_len, dtype)


def decode_step(cfg: ArchConfig, params, cache, tokens, impl: str = "auto"):
    # image prefix already sits in the cache (prefilled); plain causal decode
    return transformer.decode_step(cfg, params, cache, tokens, impl=impl)
