"""Shared neural building blocks (pure JAX, functional, pytree params)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constrain_act


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def scan_layers(cfg, body, init, xs):
    """lax.scan over stacked layer params; honours cfg.unroll_layers (used by
    the dry-run's per-layer cost extrapolation)."""
    return jax.lax.scan(body, init, xs, unroll=True if cfg.unroll_layers else 1)


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) absolute token positions."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(n_pos: int, dim: int) -> np.ndarray:
    pos = np.arange(n_pos)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


# ---------------------------------------------------------------------------
# Attention lives in repro.kernels.flash_attention (Pallas kernel + chunked
# jnp path + exact oracle); re-exported here for convenience.
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention.ops import flash_attention  # noqa: E402
from repro.kernels.flash_attention.ref import AttnSpec, attention_mask  # noqa: E402


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def weighted_cross_entropy(logits: jax.Array, labels: jax.Array,
                           weights: Optional[jax.Array] = None,
                           logit_softcap: float = 0.0) -> tuple[jax.Array, jax.Array]:
    """Per-token CE with optional per-SAMPLE weights (the Cocktail |D_j|
    aggregation of eq. 15 folds into these weights). Returns (loss, n_tokens).
    labels < 0 are masked out."""
    if logit_softcap > 0:
        logits = softcap(logits, logit_softcap)
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid
    if weights is not None:
        nll = nll * weights[:, None]
        denom = jnp.sum(valid * weights[:, None])
    else:
        denom = jnp.sum(valid)
    return jnp.sum(nll) / jnp.maximum(denom, 1.0), denom


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, scale: float = 1.0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) * scale / np.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
