"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/mel frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, enc_ctx, D) supplied by ``input_specs``.
Encoder: bidirectional self-attention + sinusoidal positions. Decoder:
causal self-attention + cross-attention to the encoder output. Decode
caches both the self-attn KV ring and the (static) cross-attn KV.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import AttnSpec
from repro.models import layers as L
from repro.parallel.sharding import constrain_act, gather_fsdp, kv_layout

_BI = AttnSpec(causal=False)
_CAUSAL = AttnSpec(causal=True)


def _init_attn(cfg, key, n_layers, prefix=""):
    d = cfg.d_model
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)

    def dense(k_, shape, in_axis=0, scale=1.0):
        w = jax.random.normal(k_, (n_layers,) + shape, jnp.float32)
        return (w * scale / np.sqrt(shape[in_axis])).astype(dt)

    return {
        prefix + "norm": jnp.zeros((n_layers, d), dt),
        prefix + "wq": dense(ks[0], (d, h, hd)),
        prefix + "wk": dense(ks[1], (d, hkv, hd)),
        prefix + "wv": dense(ks[2], (d, hkv, hd)),
        prefix + "wo": dense(ks[3], (h, hd, d), scale=np.sqrt(hd) / np.sqrt(2 * cfg.n_layers)),
    }


def _init_mlp(cfg, key, n_layers):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.param_dtype)

    def dense(k_, shape, in_axis=0, scale=1.0):
        w = jax.random.normal(k_, (n_layers,) + shape, jnp.float32)
        return (w * scale / np.sqrt(shape[in_axis])).astype(dt)

    return {
        "mlp_norm": jnp.zeros((n_layers, d), dt),
        "w_up": dense(ks[0], (d, ff)),
        "w_down": dense(ks[1], (ff, d), scale=np.sqrt(ff) / np.sqrt(2 * cfg.n_layers)),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    enc = {**_init_attn(cfg, k1, cfg.n_enc_layers), **_init_mlp(cfg, k2, cfg.n_enc_layers)}
    dec = {**_init_attn(cfg, k3, cfg.n_layers),
           **_init_attn(cfg, k4, cfg.n_layers, prefix="cross_"),
           **_init_mlp(cfg, k5, cfg.n_layers)}
    return {
        "embed": L.embed_init(k6, (cfg.vocab_size, cfg.d_model), dt),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }


def _attn_apply(cfg, x, p, prefix, q_pos, kv, kv_pos, spec, kv_valid=None, impl="auto"):
    h = L.rms_norm(x, p[prefix + "norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhf->bshf", h, gather_fsdp(p[prefix + "wq"], (None, "model", None)))
    if kv is None:  # self-attention
        k = jnp.einsum("bsd,dhf->bshf", h, gather_fsdp(p[prefix + "wk"], (None, "model", None)))
        v = jnp.einsum("bsd,dhf->bshf", h, gather_fsdp(p[prefix + "wv"], (None, "model", None)))
        if spec.causal:  # rope only on the causal decoder self-attn
            q = L.apply_rope(q, q_pos, cfg.rope_theta)
            k = L.apply_rope(k, kv_pos, cfg.rope_theta)
    else:
        k, v = kv
    attn = flash_attention(q, k, v, q_pos, kv_pos, spec, kv_valid=kv_valid, impl=impl)
    return x + jnp.einsum("bshf,hfd->bsd", attn, gather_fsdp(p[prefix + "wo"], ("model", None, None)))


def _mlp_apply(cfg, x, p):
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    ff = L.activate(jnp.einsum("bsd,df->bsf", h, gather_fsdp(p["w_up"], (None, "model"))), cfg.act)
    return x + jnp.einsum("bsf,fd->bsd", ff, gather_fsdp(p["w_down"], ("model", None)))


def encode(cfg: ArchConfig, cparams, frames, impl: str = "auto"):
    """frames: (B, enc_ctx, D) precomputed frame embeddings (stub frontend)."""
    b, s, _ = frames.shape
    pos_tab = jnp.asarray(L.sinusoidal_embedding(s, cfg.d_model))
    x = frames.astype(jnp.dtype(cfg.compute_dtype)) + pos_tab[None]
    x = constrain_act(x, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(xx, lp):
        xx = _attn_apply(cfg, xx, lp, "", positions, None, positions, _BI, impl=impl)
        xx = _mlp_apply(cfg, xx, lp)
        return xx, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = L.scan_layers(cfg, body_fn, x, cparams["enc_blocks"])
    return L.rms_norm(x, cparams["enc_norm"], cfg.norm_eps)


def forward(cfg: ArchConfig, params, tokens, frames, impl: str = "auto"):
    """Teacher-forced decoder logits: tokens (B, S), frames (B, enc_ctx, D)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    enc_out = encode(cfg, cparams, frames, impl)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1], dtype=jnp.int32),
                               (b, enc_out.shape[1]))
    x = gather_fsdp(cparams["embed"], ("model", None))[tokens].astype(cdt)

    def body(xx, lp):
        xx = _attn_apply(cfg, xx, lp, "", positions, None, positions, _CAUSAL, impl=impl)
        ck = jnp.einsum("bsd,dhf->bshf", enc_out, gather_fsdp(lp["cross_wk"], (None, "model", None)))
        cv = jnp.einsum("bsd,dhf->bshf", enc_out, gather_fsdp(lp["cross_wv"], (None, "model", None)))
        xx = _attn_apply(cfg, xx, lp, "cross_", positions, (ck, cv), enc_pos, _BI, impl=impl)
        xx = _mlp_apply(cfg, xx, lp)
        return xx, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = L.scan_layers(cfg, body_fn, x, cparams["dec_blocks"])
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, gather_fsdp(cparams["embed"], ("model", None)).T)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Self-attn KV cache + precomputed cross-attn KV (filled by prefill or
    provided as dry-run inputs)."""
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    nl, ec = cfg.n_layers, cfg.enc_ctx
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((nl, batch, max_len, hkv, hd), dt),
        "v": jnp.zeros((nl, batch, max_len, hkv, hd), dt),
        "kv_pos": jnp.full((nl, batch, max_len), -1, jnp.int32),
        "cross_k": jnp.zeros((nl, batch, ec, hkv, hd), dt),
        "cross_v": jnp.zeros((nl, batch, ec, hkv, hd), dt),
    }


def prefill_cross(cfg: ArchConfig, params, frames, cache: dict, impl="auto") -> dict:
    """Compute encoder output once and populate the cross-attn KV cache."""
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    enc_out = encode(cfg, cparams, frames, impl)
    ck = jnp.einsum("bsd,ldhf->lbshf", enc_out, cparams["dec_blocks"]["cross_wk"])
    cv = jnp.einsum("bsd,ldhf->lbshf", enc_out, cparams["dec_blocks"]["cross_wv"])
    return {**cache, "cross_k": ck.astype(cache["cross_k"].dtype),
            "cross_v": cv.astype(cache["cross_v"].dtype)}


def decode_step(cfg: ArchConfig, params, cache: dict, tokens, impl: str = "auto"):
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    x = gather_fsdp(cparams["embed"], ("model", None))[tokens].astype(cdt)
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    ec = cfg.enc_ctx
    enc_pos = jnp.broadcast_to(jnp.arange(ec, dtype=jnp.int32), (b, ec))

    def body(xx, scanned):
        lp = scanned["p"]
        kc, vc, pc = scanned["k"], scanned["v"], scanned["kv_pos"]
        slot = jnp.minimum(pos, kc.shape[1] - 1)
        h = L.rms_norm(xx, lp["norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhf->bshf", h, gather_fsdp(lp["wq"], (None, "model", None)))
        k_new = jnp.einsum("bsd,dhf->bshf", h, gather_fsdp(lp["wk"], (None, "model", None)))
        v_new = jnp.einsum("bsd,dhf->bshf", h, gather_fsdp(lp["wv"], (None, "model", None)))
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k_new = L.apply_rope(k_new, positions, cfg.rope_theta)
        if kv_layout(cfg.n_kv_heads) == "seq":
            q = constrain_act(q, ("batch", None, None, None))
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k_new.astype(kc.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v_new.astype(vc.dtype), slot, axis=1)
        pc = jax.lax.dynamic_update_slice_in_dim(
            pc, jnp.full((b, 1), pos, jnp.int32), slot, axis=1)
        attn = flash_attention(q, kc, vc, positions, pc, _CAUSAL,
                               kv_valid=pc >= 0, impl=impl)
        xx = xx + jnp.einsum("bshf,hfd->bsd", attn, lp["wo"])
        xx = _attn_apply(cfg, xx, lp, "cross_", positions,
                         (scanned["ck"], scanned["cv"]), enc_pos, _BI, impl=impl)
        xx = _mlp_apply(cfg, xx, lp)
        return xx, {"k": kc, "v": vc, "kv_pos": pc}

    scanned = {"p": cparams["dec_blocks"], "k": cache["k"], "v": cache["v"],
               "kv_pos": cache["kv_pos"], "ck": cache["cross_k"], "cv": cache["cross_v"]}
    x, outs = L.scan_layers(cfg, body, x, scanned)
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, gather_fsdp(cparams["embed"], ("model", None)).T)
    new_cache = dict(cache)
    new_cache.update({"pos": pos + 1, **outs})
    return logits, new_cache
