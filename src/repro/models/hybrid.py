"""Zamba2-style hybrid: Mamba-2 backbone + one SHARED attention+MLP block
applied every `hybrid_attn_every` layers.

The scan body is one *group* = k mamba2 layers (unrolled) + the shared
attention block, so the attention spec stays static and the shared weights
live in the scan closure (they are identical every application — only the
KV cache is per-application, carried as a scan xs/ys pair).

Simplification vs the released checkpoints (documented in DESIGN.md): the
shared block attends over the hidden stream only (no concat with the initial
embedding, no per-application LoRA deltas).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import AttnSpec
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import _project_qkv
from repro.parallel.sharding import constrain_act, gather_fsdp, kv_layout


def _n_groups(cfg: ArchConfig) -> int:
    k = cfg.hybrid_attn_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k


def _init_shared_attn(cfg: ArchConfig, key) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)

    def dense(k_, shape, in_axis=0, scale=1.0):
        w = jax.random.normal(k_, shape, jnp.float32)
        return (w * scale / np.sqrt(shape[in_axis])).astype(dt)

    return {
        "attn_norm": jnp.zeros((d,), dt),
        "wq": dense(ks[0], (d, h, hd)),
        "wk": dense(ks[1], (d, hkv, hd)),
        "wv": dense(ks[2], (d, hkv, hd)),
        "wo": dense(ks[3], (h, hd, d), scale=np.sqrt(hd) / np.sqrt(2 * cfg.n_layers)),
        "mlp_norm": jnp.zeros((d,), dt),
        "w_gate": dense(ks[4], (d, ff)),
        "w_up": dense(ks[5], (d, ff)),
        "w_down": dense(ks[6], (ff, d), scale=np.sqrt(ff) / np.sqrt(2 * cfg.n_layers)),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    k_emb, k_blocks, k_attn, k_head = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    params = {
        "embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
        "blocks": ssm.init_mamba2_stack(cfg, k_blocks, cfg.n_layers),
        "shared_attn": _init_shared_attn(cfg, k_attn),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dt)
    return params


def _shared_attn_apply(cfg, x, sp, positions, kv_override=None, impl="auto"):
    h = L.rms_norm(x, sp["attn_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, h, sp, positions)
    if kv_override is not None:
        k, v, kv_pos, kv_valid = kv_override
    else:
        kv_pos, kv_valid = positions, None
    spec = AttnSpec(causal=True)
    attn = flash_attention(q, k, v, positions, kv_pos, spec,
                           kv_valid=kv_valid, impl=impl)
    x = x + jnp.einsum("bshf,hfd->bsd", attn, gather_fsdp(sp["wo"], ("model", None, None)))
    h = L.rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
    ff = L.activate(jnp.einsum("bsd,df->bsf", h, gather_fsdp(sp["w_gate"], (None, "model"))), cfg.act)
    ff = ff * jnp.einsum("bsd,df->bsf", h, gather_fsdp(sp["w_up"], (None, "model")))
    x = x + jnp.einsum("bsf,fd->bsd", ff, gather_fsdp(sp["w_down"], ("model", None)))
    return constrain_act(x, ("batch", "seq", None)), (k, v)


def _group_params(cfg, blocks):
    k = cfg.hybrid_attn_every
    return jax.tree.map(lambda a: a.reshape((a.shape[0] // k, k) + a.shape[1:]), blocks)


def forward(cfg: ArchConfig, params, tokens, impl: str = "auto"):
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    x = gather_fsdp(cparams["embed"], ("model", None))[tokens].astype(cdt)
    x = constrain_act(x, ("batch", None, None))
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    groups = _group_params(cfg, cparams["blocks"])
    sp = cparams["shared_attn"]
    k = cfg.hybrid_attn_every

    def body(xx, group_p):
        for i in range(k):
            lp = jax.tree.map(lambda a: a[i], group_p)
            xx, _ = ssm.mamba2_block(cfg, xx, lp, impl=impl)
        xx, _ = _shared_attn_apply(cfg, xx, sp, positions, impl=impl)
        return xx, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = L.scan_layers(cfg, body_fn, x, groups)
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = gather_fsdp(cparams["embed"], ("model", None)).T
    else:
        head = gather_fsdp(cparams["head"], (None, "model"))
    return jnp.einsum("bsd,dv->bsv", x, head)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    g = _n_groups(cfg)
    di, n, kc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    heads = di // cfg.ssm_head_dim
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "pos": jnp.zeros((), jnp.int32),
        "conv": jnp.zeros((cfg.n_layers, batch, kc - 1, di), dt),
        "h": jnp.zeros((cfg.n_layers, batch, heads, n, cfg.ssm_head_dim), jnp.float32),
        "attn_k": jnp.zeros((g, batch, max_len, hkv, hd), dt),
        "attn_v": jnp.zeros((g, batch, max_len, hkv, hd), dt),
        "attn_pos": jnp.full((g, batch, max_len), -1, jnp.int32),
    }


def decode_step(cfg: ArchConfig, params, cache: dict, tokens, impl: str = "auto"):
    cdt = jnp.dtype(cfg.compute_dtype)
    cparams = L.cast_tree(params, cdt)
    x = gather_fsdp(cparams["embed"], ("model", None))[tokens].astype(cdt)
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    k = cfg.hybrid_attn_every
    groups = _group_params(cfg, cparams["blocks"])
    conv_g = cache["conv"].reshape((_n_groups(cfg), k) + cache["conv"].shape[1:])
    h_g = cache["h"].reshape((_n_groups(cfg), k) + cache["h"].shape[1:])
    sp = cparams["shared_attn"]

    def body(xx, scanned):
        new_conv, new_h = [], []
        for i in range(k):
            lp = jax.tree.map(lambda a: a[i], scanned["p"])
            st = {"conv": scanned["conv"][i], "h": scanned["h"][i]}
            xx, ns = ssm.mamba2_block(cfg, xx, lp, state=st, impl=impl)
            new_conv.append(ns["conv"])
            new_h.append(ns["h"])
        kc, vc, pc = scanned["ak"], scanned["av"], scanned["ap"]
        slot = jnp.minimum(pos, kc.shape[1] - 1)
        hn = L.rms_norm(xx, sp["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = _project_qkv(cfg, hn, sp, positions)
        if kv_layout(cfg.n_kv_heads) == "seq":
            q = constrain_act(q, ("batch", None, None, None))
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k_new.astype(kc.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v_new.astype(vc.dtype), slot, axis=1)
        pc = jax.lax.dynamic_update_slice_in_dim(
            pc, jnp.full((b, 1), pos, jnp.int32), slot, axis=1)
        attn = flash_attention(q, kc, vc, positions, pc, AttnSpec(causal=True),
                               kv_valid=pc >= 0, impl=impl)
        xx = xx + jnp.einsum("bshf,hfd->bsd", attn, gather_fsdp(sp["wo"], ("model", None, None)))
        hn = L.rms_norm(xx, sp["mlp_norm"], cfg.norm_eps)
        ff = L.activate(jnp.einsum("bsd,df->bsf", hn, gather_fsdp(sp["w_gate"], (None, "model"))), cfg.act)
        ff = ff * jnp.einsum("bsd,df->bsf", hn, gather_fsdp(sp["w_up"], (None, "model")))
        xx = xx + jnp.einsum("bsf,fd->bsd", ff, gather_fsdp(sp["w_down"], ("model", None)))
        outs = {"conv": jnp.stack(new_conv), "h": jnp.stack(new_h),
                "ak": kc, "av": vc, "ap": pc}
        return xx, outs

    scanned = {"p": groups, "conv": conv_g, "h": h_g,
               "ak": cache["attn_k"], "av": cache["attn_v"], "ap": cache["attn_pos"]}
    x, outs = L.scan_layers(cfg, body, x, scanned)
    x = L.rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = gather_fsdp(cparams["embed"], ("model", None)).T
    else:
        head = gather_fsdp(cparams["head"], (None, "model"))
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    new_cache = {
        "pos": pos + 1,
        "conv": outs["conv"].reshape(cache["conv"].shape),
        "h": outs["h"].reshape(cache["h"].shape),
        "attn_k": outs["ak"], "attn_v": outs["av"], "attn_pos": outs["ap"],
    }
    return logits, new_cache
