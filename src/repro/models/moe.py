"""Mixture-of-Experts FFN (Mixtral-style top-2) with static-shape dispatch.

TPU adaptation: instead of a ragged gather (GPU-style) we use the classic
capacity-bounded scatter: token t's k-th choice goes to slot
(expert e, position p) where p is the token's rank among e's assignees;
tokens beyond capacity C = ceil(T*K/E * cf) are dropped (standard for
TPU MoE, cf. GShard/Switch). All shapes static -> MXU-friendly einsums,
shardable: expert weight matrices keep d_ff on the TP axis; dispatch is pure
data movement on the batch shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.parallel.sharding import constrain_act, dp_group_count, gather_fsdp


def init_moe_params(cfg: ArchConfig, key, n_layers: int) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    out_scale = 1.0 / np.sqrt(2 * cfg.n_layers)

    def dense(k, shape, in_axis, scale=1.0):
        flat = jax.random.normal(k, (n_layers,) + shape, jnp.float32)
        return (flat * scale / np.sqrt(shape[in_axis])).astype(dt)

    return {
        "router": dense(ks[0], (d, e), 0),
        "we_gate": dense(ks[1], (e, d, ff), 1),
        "we_up": dense(ks[2], (e, d, ff), 1),
        "we_down": dense(ks[3], (e, ff, d), 1, scale=out_scale * np.sqrt(ff)),
    }


def capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(np.ceil(n_tokens * cfg.n_experts_per_tok / cfg.n_experts
                    * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # pad to a multiple of 8


def _shardmap_local(fn, n_in: int, out_rank: int, g: int = 0):
    """Run `fn` per DP shard (shard_map over the batch axes, model axis left
    to GSPMD). GSPMD cannot prove our dispatch scatter/gather local and
    inserts rotate-style collective-permutes; shard_map makes locality a
    guarantee instead of a heuristic (§Perf iteration 2)."""
    from repro.parallel.sharding import batch_axes, current_mesh
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    if mesh is None or g == 1:  # unsharded group dim (e.g. batch=1 decode)
        return fn
    bax = batch_axes(mesh)
    in_specs = tuple(P(bax, *([None] * r)) for r in ([2, 1, 2][:n_in]))
    out_specs = P(bax, *([None] * (out_rank - 1)))
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, axis_names=set(bax),
                         check_vma=False)


def _dispatch(upd: jax.Array, dst: jax.Array, e: int, c: int) -> jax.Array:
    """(G, TgK, D), (G, TgK) -> (G, E*C, D) shard-local scatter-add."""

    def local(u, d_idx):  # (1, TgK, D), (1, TgK) per shard
        buf = jnp.zeros((1, e * c + 1, u.shape[-1]), u.dtype)
        buf = buf.at[0, d_idx[0]].add(u[0])
        return buf[:, : e * c]

    return _shardmap_local(local, 2, 3, g=upd.shape[0])(upd, dst)


def _combine(out: jax.Array, dst: jax.Array) -> jax.Array:
    """(G, E*C, D), (G, TgK) -> (G, TgK, D) shard-local gather (spill slot
    reads zeros)."""

    def local(o, d_idx):  # (1, E*C, D), (1, TgK)
        padded = jnp.concatenate(
            [o[0], jnp.zeros((1, o.shape[-1]), o.dtype)], axis=0)
        return padded[d_idx[0]][None]

    return _shardmap_local(local, 2, 3, g=out.shape[0])(out, dst)


def moe_ffn(cfg: ArchConfig, x: jax.Array, p: dict) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    Dispatch is **shard-local**: tokens are reshaped to an explicit
    (G, T/G, ...) layout where G = number of DP shards, so the rank-cumsum,
    the scatter into expert buffers and the gather back are all batched over
    G and never cross a shard boundary (experts are replicated across DP and
    TP-sharded on d_ff, so global dispatch would buy nothing and cost a
    full-buffer all-reduce per layer — §Perf iteration 2).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    g = dp_group_count(b)  # static; 1 without a mesh
    tg = t // g
    c = capacity(cfg, tg)
    xf = x.reshape(g, tg, d)
    xf = constrain_act(xf, ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32),
                        gather_fsdp(p["router"], (None, None)).astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, Tg, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_e = gate_idx.reshape(g, tg * k)  # expert id per assignment
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (G, Tg*K, E)
    rank = jnp.cumsum(onehot, axis=1) - onehot  # rank within expert, per shard
    pos = jnp.sum(rank * onehot, axis=-1)  # (G, Tg*K)
    keep = pos < c
    dst = jnp.where(keep, flat_e * c + pos, e * c)  # spill slot at e*c

    # per-token repeat matching the (tok, k) flattening of gate_idx
    xr = jnp.reshape(
        jnp.broadcast_to(xf[:, :, None, :], (g, tg, k, d)), (g, tg * k, d))
    expert_in = _dispatch(xr * keep[..., None].astype(xf.dtype), dst, e, c)
    expert_in = constrain_act(expert_in.reshape(g, e, c, d),
                              ("batch", None, None, None))

    h = L.activate(jnp.einsum("gecd,edf->gecf", expert_in,
                              gather_fsdp(p["we_gate"], (None, None, "model"))), cfg.act)
    h = h * jnp.einsum("gecd,edf->gecf", expert_in,
                       gather_fsdp(p["we_up"], (None, None, "model")))
    h = constrain_act(h, ("batch", None, None, "model"))
    out = jnp.einsum("gecf,efd->gecd", h,
                     gather_fsdp(p["we_down"], (None, "model", None)))

    out = constrain_act(out, ("batch", None, None, None))
    gathered = _combine(out.reshape(g, e * c, d), dst)  # (G, Tg*K, D)
    weighted = gathered * (gate_vals.reshape(g, tg * k, 1).astype(out.dtype)
                           * keep[..., None].astype(out.dtype))
    y = weighted.reshape(g, tg, k, d).sum(axis=2)
    return y.reshape(b, s, d).astype(x.dtype)


def router_aux_loss(cfg: ArchConfig, x: jax.Array, p: dict) -> jax.Array:
    """Switch-style load-balancing loss: E * sum_e f_e * P_e."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    xf = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, k)
    f = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=1), axis=0)
    pm = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * pm)
