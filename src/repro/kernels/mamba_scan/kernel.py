"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation notes (vs the CUDA kernel in the Mamba paper):
  * the GPU kernel parallelises over (batch, d_inner) threads with a
    sequential scan in registers; on TPU we tile (batch, d_inner-block) on
    the grid and keep the running state h (block_d x N) resident in VMEM
    scratch across *sequence-chunk* grid steps — HBM sees x/dt/B/C exactly
    once;
  * within a chunk the recurrence runs as an in-register fori_loop over
    time; d_inner-block x N (e.g. 256 x 16) elementwise updates vectorise on
    the VPU lanes;
  * grid order (batch, d-block, chunk) with chunk innermost makes the
    carried scratch state correct without cross-step synchronisation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                 h_ref, *, n_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)  # (bd, N)

    a = a_ref[...].astype(jnp.float32)  # (bd, N)

    def step(t, carry):
        h = carry
        xt = x_ref[0, t, :].astype(jnp.float32)  # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)  # (bd,)
        bt = b_ref[0, t, :].astype(jnp.float32)  # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)  # (N,)
        da = jnp.exp(dtt[:, None] * a)  # (bd, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y_ref[0, t, :] = jnp.sum(h * ct[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == n_chunks - 1)
    def _fin():
        hout_ref[0] = h.astype(hout_ref.dtype)


def mamba1_scan_pallas(x, dt, a, b, c, h0=None, chunk: int = 256,
                       block_d: int = 256, interpret: bool = False):
    """Same contract as ops.mamba1_scan_ref: x/dt (B,S,DI), a (DI,N),
    b/c (B,S,N), h0 (B,DI,N) -> (y (B,S,DI), h (B,DI,N))."""
    bsz, s, di = x.shape
    n = a.shape[1]
    cs = min(chunk, s)
    while s % cs:
        cs //= 2
    nc = s // max(cs, 1)
    bd = min(block_d, di)
    while di % bd:
        bd //= 2
    nd = di // max(bd, 1)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    grid = (bsz, nd, nc)
    kernel = functools.partial(_scan_kernel, n_chunks=nc, chunk=cs)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cs, bd), lambda ib, id_, ic: (ib, ic, id_)),  # x
            pl.BlockSpec((1, cs, bd), lambda ib, id_, ic: (ib, ic, id_)),  # dt
            pl.BlockSpec((bd, n), lambda ib, id_, ic: (id_, 0)),  # a
            pl.BlockSpec((1, cs, n), lambda ib, id_, ic: (ib, ic, 0)),  # b
            pl.BlockSpec((1, cs, n), lambda ib, id_, ic: (ib, ic, 0)),  # c
            pl.BlockSpec((1, bd, n), lambda ib, id_, ic: (ib, id_, 0)),  # h0
        ],
        out_specs=[
            pl.BlockSpec((1, cs, bd), lambda ib, id_, ic: (ib, ic, id_)),  # y
            pl.BlockSpec((1, bd, n), lambda ib, id_, ic: (ib, id_, 0)),  # h
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), x.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, h0)
    return y, hout
