"""Sequential (exact) selective-scan oracles for Mamba-1 and Mamba-2.

These are the correctness references: plain ``lax.scan`` over time, one step
per token. The production paths (chunked matmul forms in ops.py / the Pallas
kernel) are tested against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba1_scan_ref(x, dt, a, b, c, h0=None):
    """Mamba-1 selective scan, sequential.

    x:  (B, S, DI)   input sequence (post conv + activation)
    dt: (B, S, DI)   positive step sizes (post softplus)
    a:  (DI, N)      negative state matrix (A = -exp(a_log))
    b:  (B, S, N)    input projection
    c:  (B, S, N)    output projection
    h0: (B, DI, N)   optional initial state
    Returns (y (B, S, DI), h_final (B, DI, N)).
    """
    bsz, s, di = x.shape
    n = a.shape[1]
    h0 = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,DI), (B,DI), (B,N), (B,N)
        da = jnp.exp(dtt[..., None] * a[None])  # (B, DI, N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def mamba2_scan_ref(x, dt, a, b, c, h0=None):
    """Mamba-2 (SSD) scan, sequential. Scalar decay per head.

    x:  (B, S, H, P)  head-split inputs
    dt: (B, S, H)     positive step sizes
    a:  (H,)          negative per-head decay log-rate (A = -exp(a_log))
    b:  (B, S, N)     shared (MQA-style) input projection
    c:  (B, S, N)     shared output projection
    h0: (B, H, N, P)  optional initial state
    Returns (y (B, S, H, P), h_final (B, H, N, P)).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    h0 = jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(hst, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * a[None])  # (B, H)
        upd = jnp.einsum("bn,bhp->bhnp", bt, dtt[..., None] * xt)
        hst = da[..., None, None] * hst + upd
        y = jnp.einsum("bhnp,bn->bhp", hst, ct)
        return hst, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    hst, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hst
