"""Production selective-scan paths.

* ``mamba1_scan``: chunked associative scan — within a chunk a parallel
  (log-depth) first-order recurrence, across chunks a short sequential scan
  carrying (B, DI, N) state. Live memory O(B * chunk * DI * N) instead of
  O(B * S * DI * N).
* ``mamba2_scan``: the SSD chunked *matmul* form (Dao & Gu): intra-chunk
  attention-like C@B^T masked by the decay kernel, inter-chunk via carried
  (B, H, N, P) states. This is the MXU-native TPU adaptation — all heavy ops
  are einsums over (chunk x chunk) or (N x P) tiles.

Backend dispatch mirrors flash_attention: TPU -> Pallas kernel, else jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import mamba1_scan_ref, mamba2_scan_ref


def _pick_chunk(s: int, chunk: int) -> int:
    c = min(chunk, s)
    while s % c:
        c //= 2
    return max(c, 1)


def mamba1_scan_chunked(x, dt, a, b, c, h0=None, chunk: int = 256):
    """Same contract as mamba1_scan_ref."""
    bsz, s, di = x.shape
    n = a.shape[1]
    cs = _pick_chunk(s, chunk)
    nc = s // cs
    h0 = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    xf = x.reshape(bsz, nc, cs, di).astype(jnp.float32)
    dtf = dt.reshape(bsz, nc, cs, di).astype(jnp.float32)
    bf = b.reshape(bsz, nc, cs, n).astype(jnp.float32)
    cf = c.reshape(bsz, nc, cs, n).astype(jnp.float32)

    def chunk_body(h, inp):
        xc, dtc, bc, cc = inp  # (B, cs, DI), ..., (B, cs, N)
        da = jnp.exp(dtc[..., None] * a[None, None])  # (B, cs, DI, N)
        dbx = (dtc * xc)[..., None] * bc[:, :, None, :]  # (B, cs, DI, N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        hs = a_cum * h[:, None] + b_cum  # (B, cs, DI, N)
        y = jnp.einsum("bsdn,bsn->bsd", hs, cc)
        return hs[:, -1], y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    # remat: keep the (B, cs, DI, N) chunk intermediates out of the residuals
    h, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, di).astype(x.dtype)
    return y, h


def mamba2_scan_chunked(x, dt, a, b, c, h0=None, chunk: int = 128):
    """Same contract as mamba2_scan_ref (SSD matmul form)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    cs = _pick_chunk(s, chunk)
    nc = s // cs
    h0 = jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    xf = x.reshape(bsz, nc, cs, h, p).astype(jnp.float32)
    dtf = dt.reshape(bsz, nc, cs, h).astype(jnp.float32)
    bf = b.reshape(bsz, nc, cs, n).astype(jnp.float32)
    cf = c.reshape(bsz, nc, cs, n).astype(jnp.float32)

    def chunk_body(hst, inp):
        xc, dtc, bc, cc = inp  # (B,cs,H,P), (B,cs,H), (B,cs,N), (B,cs,N)
        dta = dtc * a[None, None]  # (B, cs, H) negative increments
        cum = jnp.cumsum(dta, axis=1)  # (B, cs, H)
        # intra-chunk: decay kernel L[i,j] = exp(cum_i - cum_j), i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B, i, j, H)
        mask = jnp.tril(jnp.ones((cs, cs), bool))
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cc, bc)  # (B, i, j)
        w = cb[..., None] * lmat  # (B, i, j, H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, dtc[..., None] * xc)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", cc, hst, jnp.exp(cum))
        # state update: S <- exp(total) * S + sum_j exp(total - cum_j) dt_j B_j x_j^T
        total = cum[:, -1, :]  # (B, H)
        decay_j = jnp.exp(total[:, None, :] - cum)  # (B, cs, H)
        s_new = jnp.einsum("bjn,bjh,bjhp->bhnp", bc, decay_j * dtc, xc)
        hst = jnp.exp(total)[..., None, None] * hst + s_new
        return hst, y_intra + y_inter

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    # remat: keep the (B, cs, cs, H) decay kernel out of the residuals
    hst, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p).astype(x.dtype)
    return y, hst


def mamba1_scan(x, dt, a, b, c, h0=None, chunk: int = 256,
                impl: str = "auto", interpret: bool = False):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "chunked"
    if impl == "pallas":
        from . import kernel
        return kernel.mamba1_scan_pallas(x, dt, a, b, c, h0=h0, chunk=chunk,
                                         interpret=interpret)
    if impl == "chunked":
        return mamba1_scan_chunked(x, dt, a, b, c, h0, chunk)
    return mamba1_scan_ref(x, dt, a, b, c, h0)


def mamba2_scan(x, dt, a, b, c, h0=None, chunk: int = 128,
                impl: str = "auto", interpret: bool = False):
    if impl == "auto":
        impl = "chunked"  # SSD matmul form is already MXU-native
    if impl == "chunked":
        return mamba2_scan_chunked(x, dt, a, b, c, h0, chunk)
    return mamba2_scan_ref(x, dt, a, b, c, h0)
