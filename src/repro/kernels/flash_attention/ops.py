"""Attention front-end: backend dispatch + memory-efficient chunked jnp path.

``flash_attention`` is what models call. Dispatch:
  * TPU        -> the Pallas online-softmax kernel (kernel.py)
  * elsewhere  -> ``attention_chunked``: double-chunked (q and kv) online
                  softmax in pure jnp. O(Cq*Ck) live logits instead of
                  O(Sq*Skv); sliding-window attention reads only the
                  window-sized KV span (linear in window, not in Skv) via a
                  static-length dynamic slice — this is what makes the
                  long_500k cells lowerable.

Note (roofline): for *full causal* attention the chunked path evaluates all
(q-chunk, kv-chunk) tiles including fully-masked ones (~2x FLOP overcount vs
causal-optimal); the Pallas kernel skips them on TPU. Windowed attention is
tight on both paths. EXPERIMENTS.md corrects for this in MODEL_FLOPS ratios.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .ref import AttnSpec, attention_mask, attention_ref

_NEG = -1e30


def _chunk_sizes(sq: int, skv: int, q_chunk: int, kv_chunk: int) -> tuple[int, int]:
    qc = min(q_chunk, sq)
    while sq % qc:
        qc //= 2
    kc = min(kv_chunk, skv)
    while skv % kc:
        kc //= 2
    return max(qc, 1), max(kc, 1)


def attention_chunked(q, k, v, q_pos, kv_pos, spec: AttnSpec,
                      kv_valid=None, scale=None,
                      q_chunk: int = 1024, kv_chunk: int = 1024):
    """Online-softmax attention, chunked over q (outer scan) and kv (inner
    scan). Same signature/semantics as attention_ref."""
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    scale = hd ** -0.5 if scale is None else scale
    qc, kc = _chunk_sizes(sq, skv, q_chunk, kv_chunk)
    nq = sq // qc

    if kv_valid is None:
        kv_valid = jnp.ones((b, skv), bool)

    # Sliding window: restrict the kv span per q chunk to a static length.
    windowed = spec.window > 0 and spec.prefix_len == 0 and spec.causal
    if windowed:
        span = min(skv, -(-(spec.window + qc) // kc) * kc + kc)
    else:
        span = skv
    nk = span // kc

    q5 = q.reshape(b, nq, qc, h, hd)
    qpos3 = q_pos.reshape(b, nq, qc)

    def q_chunk_body(_, qi):
        qb = q5[:, qi]  # (B, qc, H, hd)
        qp = qpos3[:, qi]  # (B, qc)
        if windowed:
            # static-length slice covering [q_start - window + 1, q_end]
            q_start = qi * qc
            lo = jnp.clip(q_start + qc - span, 0, skv - span)
            kk = jax.lax.dynamic_slice_in_dim(k, lo, span, axis=1)
            vv = jax.lax.dynamic_slice_in_dim(v, lo, span, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_pos, lo, span, axis=1)
            kval = jax.lax.dynamic_slice_in_dim(kv_valid, lo, span, axis=1)
        else:
            kk, vv, kp, kval = k, v, kv_pos, kv_valid

        def kv_chunk_body(carry, ki):
            acc, m, l = carry
            ks = jax.lax.dynamic_slice_in_dim(kk, ki * kc, kc, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vv, ki * kc, kc, axis=1)
            kps = jax.lax.dynamic_slice_in_dim(kp, ki * kc, kc, axis=1)
            kvs = jax.lax.dynamic_slice_in_dim(kval, ki * kc, kc, axis=1)
            if group > 1:  # GQA by per-chunk head replication
                ks = jnp.repeat(ks, group, axis=2)
                vs = jnp.repeat(vs, group, axis=2)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qb.astype(jnp.float32),
                                ks.astype(jnp.float32)) * scale
            if spec.softcap > 0:
                logits = spec.softcap * jnp.tanh(logits / spec.softcap)
            mask = attention_mask(qp, kps, spec, kvs)  # (B, qc, kc)
            logits = jnp.where(mask[:, None, :, :], logits, _NEG)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vs.astype(jnp.float32))
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        m0 = jnp.full((b, h, qc), _NEG, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_chunk_body, (acc0, m0, l0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = jnp.where((m > _NEG / 2)[..., None], out, 0.0)  # fully-masked q
        return None, jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, qc, H, hd)

    # remat: without it the kv-scan stores per-iteration softmax residuals
    # for backward, re-materialising the full O(Sq*Skv) logits
    _, outs = jax.lax.scan(jax.checkpoint(q_chunk_body), None, jnp.arange(nq))
    # outs: (nq, B, qc, H, hd) -> (B, Sq, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def flash_attention(q, k, v, q_pos, kv_pos, spec: AttnSpec, kv_valid=None,
                    scale=None, impl: str = "auto",
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    interpret: bool = False):
    """Public attention entry point. impl: auto | pallas | chunked | ref."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "chunked"
    if q.shape[1] == 1 and impl == "chunked":
        # decode fast-path: single-pass exact attention. Chunking would
        # dynamic-slice a (possibly sequence-sharded) KV cache and force
        # full-cache all-gathers; the one-shot grouped einsum lets GSPMD keep
        # the contraction local per seq shard (partial softmax + small psum).
        return attention_ref(q, k, v, q_pos, kv_pos, spec, kv_valid, scale,
                             gqa="group")
    if impl == "pallas":
        from . import kernel
        return kernel.flash_attention_pallas(q, k, v, q_pos, kv_pos, spec,
                                             kv_valid=kv_valid, scale=scale,
                                             interpret=interpret)
    if impl == "chunked":
        return attention_chunked(q, k, v, q_pos, kv_pos, spec, kv_valid,
                                 scale, q_chunk, kv_chunk)
    return attention_ref(q, k, v, q_pos, kv_pos, spec, kv_valid, scale)
