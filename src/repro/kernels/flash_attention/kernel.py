"""Pallas TPU flash-attention forward kernel.

Design (TPU-native, not a CUDA port):
  * grid (B, H, n_q_blocks, n_kv_blocks), kv innermost — the online-softmax
    state (m, l, acc) lives in VMEM scratch and survives across kv steps;
  * BlockSpecs stream HBM->VMEM tiles of q (Bq x hd), k/v (Bk x hd) with the
    MXU-aligned last dims (hd and Bk are multiples of 128 for full configs);
  * GQA handled in the index map: q head h reads kv head h // group — no kv
    replication in memory;
  * causal / sliding-window / prefix masks are computed from the position
    blocks; fully-masked (q_blk, kv_blk) tiles skip the matmuls entirely via
    @pl.when (this is where the kernel beats the chunked-jnp fallback, which
    cannot skip);
  * fp32 accumulation; attention soft-capping (gemma2) fused into the tile.

The backward pass uses jax.custom_vjp with recompute-from-residuals falling
back to the chunked-jnp path — the fwd kernel is the serving/prefill hot
spot the roofline targets. Validated in interpret mode against ref.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import AttnSpec

_NEG = -1e30


def _fwd_kernel(qp_ref, kp_ref, kval_ref, q_ref, k_ref, v_ref, o_ref,
                acc_ref, m_ref, l_ref, *, spec: AttnSpec, scale: float,
                n_kv_blocks: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qp_ref[0, :]  # (Bq,)
    kv_pos = kp_ref[0, :]  # (Bk,)
    kv_ok = kval_ref[0, :]  # (Bk,) bool

    # block-level mask; skip the tile when nothing is visible
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    mask = (kp <= qp) if spec.causal else jnp.ones_like(kp <= qp)
    if spec.window > 0:
        mask = mask & (qp - kp < spec.window)
    if spec.prefix_len > 0:
        mask = mask | (kp < spec.prefix_len)
    mask = mask & kv_ok[None, :]

    @pl.when(jnp.any(mask))
    def _tile():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (Bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (Bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if spec.softcap > 0:
            logits = spec.softcap * jnp.tanh(logits / spec.softcap)
        logits = jnp.where(mask, logits, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((m_ref[...] > _NEG / 2)[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def _fwd(q, k, v, q_pos, kv_pos, spec: AttnSpec, kv_valid, scale,
         block_q: int, block_kv: int, interpret: bool):
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bk = min(block_kv, skv)
    while skv % bk:
        bk //= 2
    bq, bk = max(bq, 1), max(bk, 1)
    nq, nk = sq // bq, skv // bk
    if kv_valid is None:
        kv_valid = jnp.ones((b, skv), bool)

    grid = (b, h, nq, nk)
    kernel = functools.partial(_fwd_kernel, spec=spec, scale=scale,
                               n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda ib, ih, iq, ik: (ib, iq)),  # q_pos
            pl.BlockSpec((1, bk), lambda ib, ih, iq, ik: (ib, ik)),  # kv_pos
            pl.BlockSpec((1, bk), lambda ib, ih, iq, ik: (ib, ik)),  # kv_valid
            pl.BlockSpec((1, bq, 1, hd), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda ib, ih, iq, ik: (ib, ik, ih // group, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda ib, ih, iq, ik: (ib, ik, ih // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),  # acc
            pltpu.VMEM((bq,), jnp.float32),  # m
            pltpu.VMEM((bq,), jnp.float32),  # l
        ],
        interpret=interpret,
    )(q_pos, kv_pos, kv_valid, q, k, v)


def flash_attention_pallas(q, k, v, q_pos, kv_pos, spec: AttnSpec,
                           kv_valid=None, scale: Optional[float] = None,
                           block_q: int = 512, block_kv: int = 512,
                           interpret: bool = False):
    """Forward flash attention via Pallas; differentiable via custom_vjp with
    a chunked-jnp backward (recompute)."""
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale

    @jax.custom_vjp
    def _attn(q, k, v, q_pos, kv_pos, kv_valid):
        return _fwd(q, k, v, q_pos, kv_pos, spec, kv_valid, scale,
                    block_q, block_kv, interpret)

    def _attn_fwd(q, k, v, q_pos, kv_pos, kv_valid):
        out = _fwd(q, k, v, q_pos, kv_pos, spec, kv_valid, scale,
                   block_q, block_kv, interpret)
        return out, (q, k, v, q_pos, kv_pos, kv_valid)

    def _attn_bwd(res, g):
        from .ops import attention_chunked
        q, k, v, q_pos, kv_pos, kv_valid = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: attention_chunked(
                q_, k_, v_, q_pos, kv_pos, spec, kv_valid, scale), q, k, v)
        dq, dk, dv = vjp(g)
        return dq, dk, dv, None, None, None

    _attn.defvjp(_attn_fwd, _attn_bwd)
    if kv_valid is None:
        kv_valid = jnp.ones((q.shape[0], k.shape[1]), bool)
    return _attn(q, k, v, q_pos, kv_pos, kv_valid)
