"""Pure-jnp attention oracle + mask/spec types shared by all attention paths.

``attention_ref`` is the exact O(S^2)-memory reference the Pallas kernel and
the chunked jnp path are tested against. Supports GQA, causal / sliding
window / prefix-LM masking, attention-logit soft-capping and padded-KV
validity (decode caches).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    window: int = 0  # 0 = unlimited; >0: q attends kv with q_pos - kv_pos < window
    softcap: float = 0.0  # attention-logit tanh cap (gemma2)
    prefix_len: int = 0  # prefix-LM: kv_pos < prefix_len visible to all


def attention_mask(q_pos: jax.Array, kv_pos: jax.Array, spec: AttnSpec,
                   kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Boolean (B, Sq, Skv) mask from absolute positions (B, Sq), (B, Skv)."""
    q = q_pos[:, :, None]
    k = kv_pos[:, None, :]
    if spec.causal:
        ok = k <= q
    else:
        ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if spec.window > 0:
        ok = ok & (q - k < spec.window)
    if spec.prefix_len > 0:
        ok = ok | (k < spec.prefix_len)
    if kv_valid is not None:
        ok = ok & kv_valid[:, None, :]
    return ok


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_pos: jax.Array, kv_pos: jax.Array, spec: AttnSpec,
                  kv_valid: Optional[jax.Array] = None,
                  scale: Optional[float] = None,
                  gqa: str = "repeat") -> jax.Array:
    """Exact grouped-query attention (fp32 softmax).

    q: (B, Sq, H, hd);  k, v: (B, Skv, Hkv, hd). Returns (B, Sq, H, hd).

    gqa='repeat': replicate kv heads (sharding-friendly when q heads are on
    the TP axis — no sharded-dim reshape). gqa='group': reshape q into
    (hkv, group) — used by the decode path where q is small/replicated and
    the KV cache is sequence-sharded (repeating a sharded kv would force a
    full-cache all-gather).
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    scale = hd ** -0.5 if scale is None else scale
    mask = attention_mask(q_pos, kv_pos, spec, kv_valid)
    if group > 1 and gqa == "group":
        qg = q.reshape(b, sq, hkv, group, hd)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        if spec.softcap > 0:
            logits = spec.softcap * jnp.tanh(logits / spec.softcap)
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        any_ok = jnp.any(mask, axis=-1)[:, None, None, :, None]
        probs = probs * any_ok
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
        return out.reshape(b, sq, h, hd).astype(q.dtype)
    if group > 1:
        # GQA by head replication: keeps every einsum free of sharded-dim
        # reshapes (q heads shard on the TP axis; kv heads stay replicated).
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if spec.softcap > 0:
        logits = spec.softcap * jnp.tanh(logits / spec.softcap)
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (invalid q) produce uniform probs; zero them out
    any_ok = jnp.any(mask, axis=-1)[:, None, :, None]
    probs = probs * any_ok
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
