"""Pallas TPU kernels for the scheduler's three greedy matching hot loops.

These are the paper's scalability hot spot (Sec. III-D): the skew-aware
collection (P1'), the plain-P1 assignment (L-DS step 3 / NO-SDC) and the
Thm.-2 EC pairing all run EVERY slot of the production path, and each is a
sequential argmax-and-mask scan — awkward on accelerators because every
iteration is a full matrix reduction followed by a data-dependent scatter.

Shared TPU design (all three kernels): one grid step per selected pair. The
weight matrix lives in VMEM for the whole grid; the loop-carried state —
per-CU "assigned"/"taken" masks, per-EC connection counts, free-EC masks and
the early-stop flag — lives in VMEM/SMEM scratch that persists across grid
steps. Each step is a masked argmax (VPU reduction) plus O(1) scalar
updates, so the whole matcher runs on-chip with zero HBM round-trips for
the state. The collection kernel additionally computes the marginal
crowding penalty (n+1)log(n+1) - n log n from the on-chip counts.

All kernels are bit-exact against the jnp references in ``ref.py``
(tests/test_matching_kernels.py runs them in interpret mode on CPU); the
argmax order, penalty arithmetic and early-stop semantics mirror the refs
operation for operation. VMEM limit: the full (N, M) weight tile must fit
(N <= ~16k rows at M = 64, f32) — see README.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import _marginal_penalty

_NEG = -1e30


def _greedy_kernel(w_ref, alpha_ref, cu_taken_ref, ec_taken_ref, *, n_cu: int,
                   n_ec: int):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        cu_taken_ref[...] = jnp.zeros_like(cu_taken_ref)
        ec_taken_ref[...] = jnp.zeros_like(ec_taken_ref)
        alpha_ref[...] = jnp.zeros_like(alpha_ref)

    w = w_ref[...]  # (N, M) in VMEM
    masked = jnp.where((cu_taken_ref[...][:, None] > 0)
                       | (ec_taken_ref[...][None, :] > 0), _NEG, w)
    masked = jnp.where(w > 0, masked, _NEG)
    flat = jnp.argmax(masked)
    i, j = flat // n_ec, flat % n_ec
    best = masked.reshape(-1)[flat]
    take = best > 0.0

    @pl.when(take)
    def _take():
        cu_taken_ref[i] = 1.0
        ec_taken_ref[j] = 1.0
        alpha_ref[i, j] = 1.0


def greedy_assignment_pallas(w: jax.Array, interpret: bool = False) -> jax.Array:
    """Plain-P1 greedy assignment: w (N, M) -> alpha (N, M) in {0,1} with
    at most one EC per CU and one CU per EC, selected by descending weight.
    Requires N*M tiles to fit VMEM (N <= ~16k for M = 64)."""
    n_cu, n_ec = w.shape
    kernel = functools.partial(_greedy_kernel, n_cu=n_cu, n_ec=n_ec)
    return pl.pallas_call(
        kernel,
        grid=(n_ec,),  # one selected pair per step
        in_specs=[pl.BlockSpec((n_cu, n_ec), lambda it: (0, 0))],
        out_specs=pl.BlockSpec((n_cu, n_ec), lambda it: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cu, n_ec), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_cu,), jnp.float32),
                        pltpu.VMEM((n_ec,), jnp.float32)],
        interpret=interpret,
    )(w)


def _collection_kernel(w_ref, alpha_ref, assigned_ref, count_ref, done_ref,
                       *, n_ec: int):
    """Skew-aware P1' greedy: one connection per grid step.

    Scratch (persists across grid steps): per-CU assigned mask (VMEM), per-EC
    connection count (VMEM, f32 — exact for the small integer counts), and
    the early-stop flag (SMEM). Mirrors ``ref.greedy_collection_ref`` exactly:
    sanitize -> marginal penalty from counts -> mask assigned rows -> argmax
    -> take iff gain > 0 and not yet stopped.
    """
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        assigned_ref[...] = jnp.zeros_like(assigned_ref)
        count_ref[...] = jnp.zeros_like(count_ref)
        done_ref[0] = 0.0
        alpha_ref[...] = jnp.zeros_like(alpha_ref)

    w = w_ref[...]  # (N, M) in VMEM
    w = jnp.where(jnp.isfinite(w), w, _NEG)
    # Marginal crowding penalty of the (n+1)-th CU, from the on-chip counts.
    gain = w - _marginal_penalty(count_ref[...])[None, :]
    gain = jnp.where(assigned_ref[...][:, None] > 0, _NEG, gain)
    flat = jnp.argmax(gain)
    i, j = flat // n_ec, flat % n_ec
    best = gain.reshape(-1)[flat]
    take = (best > 0.0) & (done_ref[0] == 0.0)

    @pl.when(take)
    def _take():
        assigned_ref[i] = 1.0
        count_ref[j] = count_ref[j] + 1.0
        alpha_ref[i, j] = 1.0

    @pl.when(jnp.logical_not(take))
    def _stop():
        done_ref[0] = 1.0


def greedy_collection_pallas(logw: jax.Array, interpret: bool = False) -> jax.Array:
    """Skew-aware P1' greedy collection: logw (N, M) -> alpha (N, M) in {0,1}
    with at most one EC per CU; ECs accept multiple CUs, each new connection
    paying the marginal crowding penalty (n+1)log(n+1) - n log n. Returns
    alpha only; theta = alpha / count follows from the column sums (the
    dispatch layer computes it, matching the ref bit-exactly). Requires the
    (N, M) tile to fit VMEM."""
    n_cu, n_ec = logw.shape
    kernel = functools.partial(_collection_kernel, n_ec=n_ec)
    return pl.pallas_call(
        kernel,
        grid=(n_cu,),  # at most one connection per CU
        in_specs=[pl.BlockSpec((n_cu, n_ec), lambda it: (0, 0))],
        out_specs=pl.BlockSpec((n_cu, n_ec), lambda it: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cu, n_ec), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_cu,), jnp.float32),
                        pltpu.VMEM((n_ec,), jnp.float32),
                        pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(logw)


def _pairing_kernel(w_ref, match_ref, free_ref, done_ref, *, n_ec: int):
    """Thm.-2 EC pairing greedy: one matched pair (or solo) per grid step.

    Scratch: free-EC mask (VMEM) + early-stop flag (SMEM), persisting across
    grid steps. The diagonal of w carries the solo value, off-diagonals the
    pair value (``ref.pairing_value_matrix``); a diagonal argmax hit matches
    an EC with itself (solo training).
    """
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        free_ref[...] = jnp.ones_like(free_ref)
        done_ref[0] = 0.0
        match_ref[...] = jnp.zeros_like(match_ref)

    w = w_ref[...]  # (M, M) in VMEM
    avail = (free_ref[...][:, None] > 0) & (free_ref[...][None, :] > 0)
    g = jnp.where(avail, w, _NEG)
    flat = jnp.argmax(g)
    j, k = flat // n_ec, flat % n_ec
    best = g.reshape(-1)[flat]
    take = (best > 0.0) & (done_ref[0] == 0.0)

    @pl.when(take)
    def _take():
        free_ref[j] = 0.0
        free_ref[k] = 0.0
        match_ref[j, k] = 1.0
        match_ref[k, j] = 1.0

    @pl.when(jnp.logical_not(take))
    def _stop():
        done_ref[0] = 1.0


def greedy_pairing_pallas(w: jax.Array, interpret: bool = False) -> jax.Array:
    """Thm.-2 greedy EC pairing over the combined solo/pair value matrix
    w (M, M) (diag = solo value, off-diag = pair value; build it with
    ``ref.pairing_value_matrix``). Returns the symmetric match matrix:
    match[j,j] = 1 -> solo, match[j,k] = 1 -> paired."""
    n_ec = w.shape[0]
    kernel = functools.partial(_pairing_kernel, n_ec=n_ec)
    return pl.pallas_call(
        kernel,
        grid=(n_ec,),  # each step matches >= 1 EC (or stops)
        in_specs=[pl.BlockSpec((n_ec, n_ec), lambda it: (0, 0))],
        out_specs=pl.BlockSpec((n_ec, n_ec), lambda it: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_ec, n_ec), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_ec,), jnp.float32),
                        pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(w)
