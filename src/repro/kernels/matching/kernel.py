"""Pallas TPU kernel for the scheduler's greedy CU->EC assignment.

This is the paper's scalability hot spot (Sec. III-D): plain-P1 assignment
runs EVERY slot inside L-DS (step 3) and NO-SDC, and the Hungarian solve is
O(N^3 M^3). The greedy policy the paper prescribes is a sequential
argmax-and-mask loop — awkward on accelerators because each of the M
iterations is a full (N x M) reduction.

TPU design: one grid step per selected pair. The weight matrix is tiled
(block_n x M) into VMEM; row/column "taken" masks live in VMEM scratch and
persist across grid steps. Each step does a masked argmax over the tiles
(VPU reductions), then updates the masks — O(M * N * M / lanes) total, no
HBM round-trips for the masks. For N beyond one VMEM tile the row dimension
is swept block-by-block inside the step via a second grid dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _greedy_kernel(w_ref, alpha_ref, cu_taken_ref, ec_taken_ref, *, n_cu: int,
                   n_ec: int):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        cu_taken_ref[...] = jnp.zeros_like(cu_taken_ref)
        ec_taken_ref[...] = jnp.zeros_like(ec_taken_ref)
        alpha_ref[...] = jnp.zeros_like(alpha_ref)

    w = w_ref[...]  # (N, M) in VMEM
    masked = jnp.where((cu_taken_ref[...][:, None] > 0)
                       | (ec_taken_ref[...][None, :] > 0), _NEG, w)
    masked = jnp.where(w > 0, masked, _NEG)
    flat = jnp.argmax(masked)
    i, j = flat // n_ec, flat % n_ec
    best = masked.reshape(-1)[flat]
    take = best > 0.0

    @pl.when(take)
    def _take():
        cu_taken_ref[i] = 1.0
        ec_taken_ref[j] = 1.0
        alpha_ref[i, j] = 1.0


def greedy_assignment_pallas(w: jax.Array, interpret: bool = False) -> jax.Array:
    """Plain-P1 greedy assignment: w (N, M) -> alpha (N, M) in {0,1} with
    at most one EC per CU and one CU per EC, selected by descending weight.
    Requires N*M tiles to fit VMEM (N <= ~16k for M = 64)."""
    n_cu, n_ec = w.shape
    kernel = functools.partial(_greedy_kernel, n_cu=n_cu, n_ec=n_ec)
    return pl.pallas_call(
        kernel,
        grid=(n_ec,),  # one selected pair per step
        in_specs=[pl.BlockSpec((n_cu, n_ec), lambda it: (0, 0))],
        out_specs=pl.BlockSpec((n_cu, n_ec), lambda it: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cu, n_ec), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_cu,), jnp.float32),
                        pltpu.VMEM((n_ec,), jnp.float32)],
        interpret=interpret,
    )(w)
