"""Dispatch for the greedy-assignment kernel."""
from __future__ import annotations

import jax

from .kernel import greedy_assignment_pallas
from .ref import greedy_assignment_ref


def greedy_assignment(w, impl: str = "auto", interpret: bool = False):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return greedy_assignment_pallas(w, interpret=interpret)
    return greedy_assignment_ref(w)
