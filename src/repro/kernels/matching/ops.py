"""Dispatch layer for the three greedy matching primitives.

These are the production entry points for every per-slot subproblem solver in
the core scheduler:

  * ``greedy_collection``  — skew-aware P1' (``datasche._collect_skew``)
  * ``greedy_assignment``  — plain P1 (``datasche._collect_plain``, the L-DS
    virtual step and NO-SDC)
  * ``greedy_pairing``     — Thm.-2 EC pairing (``datasche._train_generic``)

Each routes to the Pallas kernel on TPU and the (bit-identical) jnp reference
elsewhere; ``impl=`` forces a backend and ``interpret=True`` runs the Pallas
kernel in interpreter mode (the CPU parity tests).

Batch-compatible: weights with leading batch axes — e.g. a (K, N, M) fleet
slice axis — are handled by vmapping the 2-D primitive, and calling the 2-D
form under an outer ``jax.vmap`` works as usual (the refs are pure jnp; the
Pallas calls rely on JAX's pallas_call batching rule).

Mask-aware (ragged fleets): optional ``cu_mask`` (..., N) / ``ec_mask``
(..., M) entity masks force the weight of any pair touching a padded entity
to the large negative ``MASKED_WEIGHT`` before dispatch, so neither backend
can ever select it. Masking happens here, once, so the Pallas kernels and
the jnp refs stay mask-free and bit-identical to each other.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import MASKED_WEIGHT as _MASKED
from repro.core.types import mask_pairs

from .kernel import (greedy_assignment_pallas, greedy_collection_pallas,
                     greedy_pairing_pallas)
from .ref import (greedy_assignment_ref, greedy_collection_ref,
                  greedy_pairing_ref, pairing_value_matrix)


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in ("pallas", "ref"):
        raise ValueError(f"unknown matching impl {impl!r}; "
                         "expected 'auto', 'pallas' or 'ref'")
    return impl


def _entity_masked(w, cu_mask, ec_mask):
    """Default missing masks to all-ones and force masked pairs of the
    (..., N, M) weights to MASKED_WEIGHT; no-op when neither mask is given."""
    if cu_mask is None and ec_mask is None:
        return w
    cu = cu_mask if cu_mask is not None else jnp.ones_like(w[..., :, 0])
    ec = ec_mask if ec_mask is not None else jnp.ones_like(w[..., 0, :])
    return mask_pairs(w, cu, ec)


def _dispatch(operands, impl, interpret, pallas_fn, ref_fn):
    """Shared dispatch tail of every op (masking already applied): resolve
    the impl once, vmap away any leading batch axes (the LAST operand is the
    rank-2 reference — (N, M) weights or the (M, M) pair values), then route
    to the Pallas kernel or the jnp ref."""
    impl = _resolve_impl(impl)
    if operands[-1].ndim > 2:
        return jax.vmap(lambda *ops: _dispatch(
            ops, impl, interpret, pallas_fn, ref_fn))(*operands)
    if impl == "pallas":
        return pallas_fn(*operands, interpret)
    return ref_fn(*operands)


def _assignment_pallas(w, interpret):
    return greedy_assignment_pallas(w, interpret=interpret)


def _collection_pallas(logw, interpret):
    alpha = greedy_collection_pallas(logw, interpret=interpret)
    # theta = 1/n_j from the column sums — the same arithmetic the ref
    # applies to its count vector, so the pair stays bit-exact.
    count = jnp.sum(alpha, axis=0)
    return alpha, alpha / jnp.maximum(count[None, :], 1.0)


def _pairing_pallas(solo, pair, interpret):
    return greedy_pairing_pallas(pairing_value_matrix(solo, pair),
                                 interpret=interpret)


def greedy_assignment(w, cu_mask: Optional[jax.Array] = None,
                      ec_mask: Optional[jax.Array] = None,
                      impl: str = "auto", interpret: bool = False):
    """Plain-P1 assignment: w (..., N, M) -> alpha (..., N, M) in {0,1} with
    at most one EC per CU and one CU per EC, by descending weight."""
    w = _entity_masked(w, cu_mask, ec_mask)
    return _dispatch((w,), impl, interpret, _assignment_pallas,
                     greedy_assignment_ref)


def greedy_collection(logw, cu_mask: Optional[jax.Array] = None,
                      ec_mask: Optional[jax.Array] = None,
                      impl: str = "auto", interpret: bool = False):
    """Skew-aware P1' collection: logw (..., N, M) log-weights -> (alpha,
    theta), both (..., N, M); theta = 1/n_j on the selected connections.

    Masked entities are forced to MASKED_WEIGHT before dispatch (non-finite
    inputs are sanitized the same way by both backends), so a padded pair can
    never be connected."""
    logw = _entity_masked(logw, cu_mask, ec_mask)
    return _dispatch((logw,), impl, interpret, _collection_pallas,
                     greedy_collection_ref)


def greedy_pairing(solo, pair, ec_mask: Optional[jax.Array] = None,
                   impl: str = "auto", interpret: bool = False):
    """Thm.-2 EC pairing: solo (..., M) and pair (..., M, M) values -> the
    symmetric match matrix (..., M, M); match[j,j]=1 solo, match[j,k]=1
    paired.

    A masked EC gets MASKED_WEIGHT solo and pair values, so it can neither
    train alone nor shadow a real EC's solo option through a (real, padded)
    pair."""
    if ec_mask is not None:
        solo = jnp.where(ec_mask > 0, solo, jnp.full_like(solo, _MASKED))
        pair = mask_pairs(pair, ec_mask, ec_mask)
    return _dispatch((solo, pair), impl, interpret, _pairing_pallas,
                     greedy_pairing_ref)
