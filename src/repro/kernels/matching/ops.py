"""Dispatch for the greedy-assignment kernel.

This is the production entry point used by the core scheduler's plain-P1
collection path (`repro.core.datasche._collect_plain`): the Pallas kernel on
TPU, the (bit-identical) jnp sequential greedy elsewhere.

Batch-compatible: weights with leading batch axes — e.g. a (K, N, M) fleet
slice axis — are handled by vmapping the 2-D primitive, and calling the 2-D
form under an outer ``jax.vmap`` works as usual (the ref is pure jnp; the
Pallas call relies on JAX's pallas_call batching rule).

Mask-aware (ragged fleets): optional ``cu_mask`` (..., N) / ``ec_mask``
(..., M) entity masks force the weight of any (CU, EC) pair touching a
padded entity to a large negative before dispatch, so neither backend can
ever assign it. Masking happens here, once, so the Pallas kernel and the
jnp ref stay mask-free and bit-identical to each other.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import mask_pairs

from .kernel import greedy_assignment_pallas
from .ref import greedy_assignment_ref


def greedy_assignment(w, cu_mask: Optional[jax.Array] = None,
                      ec_mask: Optional[jax.Array] = None,
                      impl: str = "auto", interpret: bool = False):
    if cu_mask is not None or ec_mask is not None:
        cu = cu_mask if cu_mask is not None else jnp.ones_like(w[..., :, 0])
        ec = ec_mask if ec_mask is not None else jnp.ones_like(w[..., 0, :])
        w = mask_pairs(w, cu, ec)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if w.ndim > 2:
        return jax.vmap(
            lambda ww: greedy_assignment(ww, impl=impl, interpret=interpret)
        )(w)
    if impl == "pallas":
        return greedy_assignment_pallas(w, interpret=interpret)
    return greedy_assignment_ref(w)
