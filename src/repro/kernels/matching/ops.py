"""Dispatch for the greedy-assignment kernel.

This is the production entry point used by the core scheduler's plain-P1
collection path (`repro.core.datasche._collect_plain`): the Pallas kernel on
TPU, the (bit-identical) jnp sequential greedy elsewhere.

Batch-compatible: weights with leading batch axes — e.g. a (K, N, M) fleet
slice axis — are handled by vmapping the 2-D primitive, and calling the 2-D
form under an outer ``jax.vmap`` works as usual (the ref is pure jnp; the
Pallas call relies on JAX's pallas_call batching rule).
"""
from __future__ import annotations

import jax

from .kernel import greedy_assignment_pallas
from .ref import greedy_assignment_ref


def greedy_assignment(w, impl: str = "auto", interpret: bool = False):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if w.ndim > 2:
        return jax.vmap(
            lambda ww: greedy_assignment(ww, impl=impl, interpret=interpret)
        )(w)
    if impl == "pallas":
        return greedy_assignment_pallas(w, interpret=interpret)
    return greedy_assignment_ref(w)
