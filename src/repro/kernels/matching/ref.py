"""jnp reference implementations of the three greedy matchers.

These ARE the production semantics: the Pallas kernels in ``kernel.py`` must
reproduce them bit-for-bit (tests/test_matching_kernels.py), and on non-TPU
backends the dispatch layer (``ops.py``) runs them directly. The paper itself
recommends 0.5-approximation greedy matching "in practice" (Sec. III-D);
exact oracles for the Thm.-1 / Thm.-2 graph constructions live in
``repro.core.oracle`` (networkx blossom, host-side).

Historically these lived in ``repro.core.matching``; that module is now a
thin re-export shim so the kernel package owns the reference semantics and
the dependency points core -> kernels (no cycle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


def _marginal_penalty(n: jax.Array) -> jax.Array:
    """(n+1)log(n+1) - n log(n): marginal crowding penalty of adding the
    (n+1)-th CU to an EC under the optimal theta = 1/n time split."""
    n = n.astype(jnp.float32)
    return (n + 1.0) * jnp.log(n + 1.0) - n * jnp.where(n > 0, jnp.log(jnp.maximum(n, 1.0)), 0.0)


def greedy_collection_ref(logw: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Greedy solve of P1' (skew-aware collection).

    Equivalent to greedy maximum-weight matching on the Thm.-1 bipartite graph
    with N virtual EC copies: repeatedly connect the (CU, EC) pair with the
    largest marginal gain  logw[i,j] - [(n_j+1)log(n_j+1) - n_j log n_j]
    until no pair has positive gain.

    Args:
      logw: (N, M) log of collection weight w_ij = d_ij (mu_i - eta_ij - c_ij);
            -inf (or very negative) where w_ij <= 0.
    Returns:
      alpha (N, M) in {0,1} and theta (N, M) with theta = 1/n_j on connections.
    """
    n_cu, n_ec = logw.shape
    logw = jnp.where(jnp.isfinite(logw), logw, _NEG)

    def body(_, state):
        assigned, count, alpha, done = state
        gain = logw - _marginal_penalty(count)[None, :]
        gain = jnp.where(assigned[:, None], _NEG, gain)
        flat = jnp.argmax(gain)
        i, j = flat // n_ec, flat % n_ec
        best = gain[i, j]
        take = (best > 0.0) & (~done)
        assigned = assigned.at[i].set(jnp.where(take, True, assigned[i]))
        count = count.at[j].add(jnp.where(take, 1, 0))
        alpha = alpha.at[i, j].set(jnp.where(take, 1.0, alpha[i, j]))
        return assigned, count, alpha, done | (~take)

    state = (
        jnp.zeros((n_cu,), bool),
        jnp.zeros((n_ec,), jnp.int32),
        jnp.zeros((n_cu, n_ec), jnp.float32),
        jnp.asarray(False),
    )
    assigned, count, alpha, _ = jax.lax.fori_loop(0, n_cu, body, state)
    theta = alpha / jnp.maximum(count[None, :].astype(jnp.float32), 1.0)
    return alpha, theta


def greedy_assignment_ref(w: jax.Array) -> jax.Array:
    """Plain P1 (non-skew-aware collection, used by L-DS step 3 / NO-SDC):
    each EC gives its whole slot to one CU; select M disjoint (CU, EC) pairs
    by descending weight (the paper's prescribed O(NM log NM) policy).

    Args:
      w: (N, M) linear weights d_ij (mu_i - eta_ij - c_ij); only w>0 usable.
    Returns:
      alpha (N, M) in {0,1}; theta is alpha itself (full slot).
    """
    n_cu, n_ec = w.shape
    w = jnp.where(w > 0, w, _NEG)

    def body(_, state):
        cu_free, ec_free, alpha = state
        avail = cu_free[:, None] & ec_free[None, :]
        g = jnp.where(avail, w, _NEG)
        flat = jnp.argmax(g)
        i, j = flat // n_ec, flat % n_ec
        take = g[i, j] > 0.0
        cu_free = cu_free.at[i].set(jnp.where(take, False, cu_free[i]))
        ec_free = ec_free.at[j].set(jnp.where(take, False, ec_free[j]))
        alpha = alpha.at[i, j].set(jnp.where(take, 1.0, alpha[i, j]))
        return cu_free, ec_free, alpha

    state = (jnp.ones((n_cu,), bool), jnp.ones((n_ec,), bool), jnp.zeros((n_cu, n_ec), jnp.float32))
    _, _, alpha = jax.lax.fori_loop(0, n_ec, body, state)
    return alpha


def pairing_value_matrix(solo: jax.Array, pair: jax.Array) -> jax.Array:
    """The (M, M) value matrix the Thm.-2 greedy scans: off-diagonal entries
    carry the pair value, the diagonal the solo value. Shared by the ref and
    the Pallas dispatch path so both matchers see bit-identical inputs."""
    n_ec = solo.shape[0]
    return pair * (1.0 - jnp.eye(n_ec)) + jnp.diag(solo)


def greedy_pairing_ref(solo: jax.Array, pair: jax.Array) -> jax.Array:
    """Greedy solve of the Thm.-2 EC-pairing matching.

    Nodes are ECs; a self-loop (virtual node j') carries the solo-training
    value, an edge (j,k) the pair-training value. Greedy maximum-weight
    matching: repeatedly take the best available entry with positive value.

    Args:
      solo: (M,) optimal solo objective per EC (problem 20).
      pair: (M, M) optimal pair objective (problem 21), symmetric, diag unused.
    Returns:
      match: (M, M) float matrix; match[j,j]=1 -> solo, match[j,k]=1 -> paired.
    """
    n_ec = solo.shape[0]
    w = pairing_value_matrix(solo, pair)

    def body(_, state):
        free, match, done = state
        avail = free[:, None] & free[None, :]
        g = jnp.where(avail, w, _NEG)
        flat = jnp.argmax(g)
        j, k = flat // n_ec, flat % n_ec
        take = (g[j, k] > 0.0) & (~done)
        free = free.at[j].set(jnp.where(take, False, free[j]))
        free = free.at[k].set(jnp.where(take, False, free[k]))
        match = match.at[j, k].set(jnp.where(take, 1.0, match[j, k]))
        match = match.at[k, j].set(jnp.where(take, 1.0, match[k, j]))
        return free, match, done | (~take)

    state = (jnp.ones((n_ec,), bool), jnp.zeros((n_ec, n_ec), jnp.float32), jnp.asarray(False))
    _, match, _ = jax.lax.fori_loop(0, n_ec, body, state)
    return match


__all__ = ["greedy_collection_ref", "greedy_assignment_ref",
           "greedy_pairing_ref", "pairing_value_matrix", "_marginal_penalty"]
