"""Oracle for the greedy-assignment kernel: the (already tested) jnp
sequential greedy from the core scheduler."""
from repro.core.matching import greedy_assignment as greedy_assignment_ref

__all__ = ["greedy_assignment_ref"]
