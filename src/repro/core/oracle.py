"""Exact matching oracles (numpy + networkx, host-side).

These implement the paper's Thm.-1 / Thm.-2 graph constructions literally and
solve them with networkx's maximum-weight matching (blossom) — the same
tooling the paper's testbed used. They are the ground truth the greedy JAX
paths in ``repro.core.matching`` are tested against, and back the ``exact``
scheduler mode.
"""
from __future__ import annotations

import math

import networkx as nx
import numpy as np


def exact_collection(logw: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Optimal P1' via max-weight matching on the Thm.-1 bipartite graph.

    Virtual EC copies (j, n) carry edge weight
        omega^n_ij = logw[i,j] - [n log n - (n-1) log(n-1)]
    so the total matched weight equals the P1' objective (marginal-gain
    telescoping). Returns (alpha, theta).

    Edges with non-positive weight are pruned: blossom with
    ``maxcardinality=False`` never includes them (dropping such an edge never
    lowers the matched weight), and since the crowding penalty grows with n
    the inner loop can stop at the first non-positive copy — without this the
    graph is O(N^2 M) edges and exact mode crawls at simulation scale.
    """
    n_cu, n_ec = logw.shape
    g = nx.Graph()
    for i in range(n_cu):
        for j in range(n_ec):
            if not np.isfinite(logw[i, j]):
                continue
            for n in range(1, n_cu + 1):
                pen = n * math.log(n) - (n - 1) * (math.log(n - 1) if n > 1 else 0.0)
                wt = float(logw[i, j]) - pen
                if wt <= 0.0:
                    break  # pen is increasing in n: all later copies are <= 0 too
                g.add_edge(("cu", i), ("ec", j, n), weight=wt)
    match = nx.max_weight_matching(g, maxcardinality=False)
    alpha = np.zeros((n_cu, n_ec), np.float32)
    for a, b in match:
        if a[0] == "ec":
            a, b = b, a
        alpha[a[1], b[1]] = 1.0
    count = alpha.sum(axis=0)
    theta = alpha / np.maximum(count[None, :], 1.0)
    return alpha, theta


def collection_objective(logw: np.ndarray, alpha: np.ndarray) -> float:
    """P1' objective for a given connection pattern (theta = 1/n_j optimal)."""
    total = 0.0
    for j in range(logw.shape[1]):
        idx = np.nonzero(alpha[:, j])[0]
        n = len(idx)
        if n == 0:
            continue
        total += float(np.sum(logw[idx, j])) - n * math.log(n)
    return total


def exact_pairing(solo: np.ndarray, pair: np.ndarray) -> np.ndarray:
    """Optimal Thm.-2 matching: nodes {EC j} + virtual {j'}; edge (j,j') has
    the solo value, (j,k) the pair value. Blossom via networkx."""
    m = solo.shape[0]
    g = nx.Graph()
    for j in range(m):
        g.add_edge(("ec", j), ("v", j), weight=float(solo[j]))
        for k in range(j + 1, m):
            g.add_edge(("ec", j), ("ec", k), weight=float(pair[j, k]))
    match = nx.max_weight_matching(g, maxcardinality=False)
    out = np.zeros((m, m), np.float32)
    for a, b in match:
        if a[0] == "v":
            a, b = b, a
        if b[0] == "v":
            out[a[1], a[1]] = 1.0
        else:
            out[a[1], b[1]] = 1.0
            out[b[1], a[1]] = 1.0
    return out


def exact_assignment(w: np.ndarray) -> np.ndarray:
    """Optimal plain-P1 assignment (each EC -> one CU, disjoint) via
    max-weight bipartite matching; used as oracle for greedy_assignment."""
    n_cu, n_ec = w.shape
    g = nx.Graph()
    for i in range(n_cu):
        for j in range(n_ec):
            if w[i, j] > 0:
                g.add_edge(("cu", i), ("ec", j), weight=float(w[i, j]))
    match = nx.max_weight_matching(g, maxcardinality=False)
    alpha = np.zeros((n_cu, n_ec), np.float32)
    for a, b in match:
        if a[0] == "ec":
            a, b = b, a
        alpha[a[1], b[1]] = 1.0
    return alpha
