"""Stochastic network-state generation.

The paper drives per-slot dynamics with two measured distributions (Fig. 4):
  * normalized cellular traffic (city-cellular-traffic-map) -> transmission
    capacity = baseline * (1 - traffic_t)
  * normalized cluster workload (Google trace)              -> computing
    capacity = baseline * (1 - workload_t)
and 0-1 uniform dynamics for unit costs and data arrivals.

We reproduce the *shape* of those curves with parametric samplers:
  traffic  ~ diurnal sinusoid + Beta noise, clipped to [0, 0.95]  (Fig. 4b is
             right-skewed with a wide body)
  workload ~ Beta(2, 5) centred low with occasional spikes        (Fig. 4c)

Sampling is **entity-keyed**: every matrix/vector element draws from its own
``fold_in(fold_in(key, i), j)`` key, so the value at (i, j) depends only on
the slot key and the entity indices — never on the array shape. This makes
the generator padding-invariant: a slice zero-padded to a larger
``ShapeConfig`` (ragged fleets) sees bit-identical draws on its real block,
and the ``cu_mask`` / ``ec_mask`` in ``SliceParams`` zero out capacity and
arrivals of padded entities so they can never carry traffic or work.

Everything is jittable; one call produces the full NetworkState for slot t.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .types import (CocktailConfig, NetworkState, ShapeConfig, SliceParams,
                    entity_masks, split_config)


def _fold_vec(key: jax.Array, n: int) -> jax.Array:
    """(n,) per-entity keys; element i depends only on (key, i)."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))


def _fold_grid(key: jax.Array, n: int, m: int) -> jax.Array:
    """(n, m) per-entity-pair keys; element (i, j) depends only on (key, i, j)."""
    return jax.vmap(lambda kr: _fold_vec(kr, m))(_fold_vec(key, n))


def _uniform_vec(key, n, minval=0.0, maxval=1.0):
    draw = lambda k: jax.random.uniform(k, (), minval=minval, maxval=maxval)
    return jax.vmap(draw)(_fold_vec(key, n))


def _uniform_grid(key, n, m, minval=0.0, maxval=1.0):
    draw = lambda k: jax.random.uniform(k, (), minval=minval, maxval=maxval)
    return jax.vmap(jax.vmap(draw))(_fold_grid(key, n, m))


def _beta_vec(key, n, a, b):
    return jax.vmap(lambda k: jax.random.beta(k, a, b))(_fold_vec(key, n))


def _beta_grid(key, n, m, a, b):
    return jax.vmap(jax.vmap(lambda k: jax.random.beta(k, a, b)))(
        _fold_grid(key, n, m))


def _traffic(key: jax.Array, n: int, m: int, t: jax.Array) -> jax.Array:
    """Normalized traffic in [0, 0.95]: diurnal base + Beta(2,4) noise."""
    k1, k2 = jax.random.split(key)
    phase = _uniform_grid(k1, n, m, minval=0.0, maxval=2 * jnp.pi)
    diurnal = 0.35 + 0.3 * jnp.sin(2 * jnp.pi * t / 288.0 + phase)  # 5-min slots
    noise = _beta_grid(k2, n, m, 2.0, 4.0) * 0.4
    return jnp.clip(diurnal + noise, 0.0, 0.95)


def _workload(key: jax.Array, m: int) -> jax.Array:
    """Normalized co-tenant workload in [0, 0.9] (Beta(2,5): mostly low)."""
    return jnp.clip(_beta_vec(key, m, 2.0, 5.0), 0.0, 0.9)


def sample_network_state(
    key: jax.Array, cfg: CocktailConfig | ShapeConfig, t: jax.Array,
    params: Optional[SliceParams] = None,
) -> NetworkState:
    shape, params = split_config(cfg, params)
    n, m = shape.n_cu, shape.n_ec
    kd, kD, kf, kc, ke, kp, ka, kh = jax.random.split(key, 8)

    # CU-EC capacity: baseline * (1 - traffic). Heterogeneous per-link baseline
    # (paper Sec. IV-C derives it from node distance); we draw a static-ish
    # multiplier from the key hash of the pair so links are persistently
    # heterogeneous across slots.
    link_het = 0.5 + _uniform_grid(jax.random.fold_in(kh, 0), n, m)
    d = params.d_base * link_het * (1.0 - _traffic(kd, n, m, t))

    ec_het = 0.5 + _uniform_grid(jax.random.fold_in(kh, 1), m, m)
    cap_d = params.cap_d_base * ec_het * (1.0 - _traffic(kD, m, m, t))
    cap_d = 0.5 * (cap_d + cap_d.T)
    cap_d = cap_d * (1.0 - jnp.eye(m))

    f = params.f_base * (1.0 - _workload(kf, m))

    # Unit costs: baseline * (1 + U(0,1)) - "dynamics following 0-1 uniform".
    c = params.c_base * (1.0 + _uniform_grid(kc, n, m))
    e = params.e_base * (1.0 + _uniform_grid(ke, m, m))
    e = 0.5 * (e + e.T) * (1.0 - jnp.eye(m))
    p = params.p_base * (1.0 + _uniform_vec(kp, m))

    arrivals = params.zeta * (0.5 + _uniform_vec(ka, n))  # E[A_i] = zeta_i

    # Ragged padding: masked entities have no capacity, generate no data and
    # can do no work; unit costs stay finite (they only ever multiply zeros).
    cu_mask, ec_mask = entity_masks(params)
    link_mask = cu_mask[:, None] * ec_mask[None, :]
    pair_mask = ec_mask[:, None] * ec_mask[None, :]
    return NetworkState(
        d=(d * link_mask).astype(jnp.float32),
        cap_d=(cap_d * pair_mask).astype(jnp.float32),
        f=(f * ec_mask).astype(jnp.float32),
        c=c.astype(jnp.float32),
        e=e.astype(jnp.float32),
        p=p.astype(jnp.float32),
        arrivals=(arrivals * cu_mask).astype(jnp.float32),
    )


def framework_cost(net: NetworkState, collected: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Per-slot framework cost C(t), eq. (14).

    collected[i,j] = alpha*theta*d samples moved CU i -> EC j.
    trained_at[i,k] = x[i,k] + sum_j y[i,j,k].
    """
    trans_cu = jnp.sum(net.c * collected)
    trans_ec = jnp.sum(net.e[None, :, :] * y)  # e[j,k] per sample moved j->k
    trained_at = x + jnp.sum(y, axis=1)  # (N, M): trained at EC k
    compute = jnp.sum(net.p[None, :] * trained_at)
    return trans_cu + trans_ec + compute
