"""Stochastic network-state generation.

The paper drives per-slot dynamics with two measured distributions (Fig. 4):
  * normalized cellular traffic (city-cellular-traffic-map) -> transmission
    capacity = baseline * (1 - traffic_t)
  * normalized cluster workload (Google trace)              -> computing
    capacity = baseline * (1 - workload_t)
and 0-1 uniform dynamics for unit costs and data arrivals.

We reproduce the *shape* of those curves with parametric samplers:
  traffic  ~ diurnal sinusoid + Beta noise, clipped to [0, 0.95]  (Fig. 4b is
             right-skewed with a wide body)
  workload ~ Beta(2, 5) centred low with occasional spikes        (Fig. 4c)

Sampling is **entity-keyed**: every matrix/vector element draws from its own
``fold_in(fold_in(key, i), j)`` key, so the value at (i, j) depends only on
the slot key and the entity indices — never on the array shape. This makes
the generator padding-invariant: a slice zero-padded to a larger
``ShapeConfig`` (ragged fleets) sees bit-identical draws on its real block,
and the ``cu_mask`` / ``ec_mask`` in ``SliceParams`` zero out capacity and
arrivals of padded entities so they can never carry traffic or work.

Two random streams drive each slot:

  * the **per-slot key** (split off ``SchedulerState.rng`` each slot) draws
    everything i.i.d. across slots — traffic/workload noise, unit costs,
    arrivals;
  * the **slot-invariant ``het_key``** (``types.het_key_from_seed``, carried
    unchanged in ``SchedulerState.het_key``) draws the *persistent* structure
    — per-link/per-EC capacity multipliers and the diurnal phases
    (:func:`heterogeneity`). This is the capacity heterogeneity driving the
    paper's data-skew problem; deriving it from the per-slot key (the old
    behaviour) silently resampled it i.i.d. every slot, so the skew the
    scheduler is built to fight never persisted.

Everything is jittable; one call produces the full NetworkState for slot t.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .types import (CocktailConfig, NetworkState, ShapeConfig, SliceParams,
                    entity_masks, het_key_from_seed, split_config)


def _fold_vec(key: jax.Array, n: int) -> jax.Array:
    """(n,) per-entity keys; element i depends only on (key, i)."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))


def _fold_grid(key: jax.Array, n: int, m: int) -> jax.Array:
    """(n, m) per-entity-pair keys; element (i, j) depends only on (key, i, j)."""
    return jax.vmap(lambda kr: _fold_vec(kr, m))(_fold_vec(key, n))


def _uniform_vec(key, n, minval=0.0, maxval=1.0):
    draw = lambda k: jax.random.uniform(k, (), minval=minval, maxval=maxval)
    return jax.vmap(draw)(_fold_vec(key, n))


def _uniform_grid(key, n, m, minval=0.0, maxval=1.0):
    draw = lambda k: jax.random.uniform(k, (), minval=minval, maxval=maxval)
    return jax.vmap(jax.vmap(draw))(_fold_grid(key, n, m))


def _beta_vec(key, n, a, b):
    return jax.vmap(lambda k: jax.random.beta(k, a, b))(_fold_vec(key, n))


def _beta_grid(key, n, m, a, b):
    return jax.vmap(jax.vmap(lambda k: jax.random.beta(k, a, b)))(
        _fold_grid(key, n, m))


class Heterogeneity(NamedTuple):
    """Slot-invariant structure of the network: pure function of ``het_key``.

    ``link_het``/``ec_het`` are the "static-ish" capacity multipliers (paper
    Sec. IV-C derives them from node distance); ``phase_d``/``phase_D`` the
    per-link diurnal phases of the traffic sinusoid. All entity-keyed, so the
    draws are padding-invariant like every other sampler here."""

    link_het: jax.Array  # (N, M) CU->EC capacity multiplier, U[0.5, 1.5]
    ec_het: jax.Array  # (M, M) EC<->EC capacity multiplier, U[0.5, 1.5]
    phase_d: jax.Array  # (N, M) diurnal phase of the CU->EC traffic
    phase_D: jax.Array  # (M, M) diurnal phase of the EC<->EC traffic


def heterogeneity(het_key: jax.Array, n: int, m: int) -> Heterogeneity:
    """Draw the persistent heterogeneity from the slot-invariant ``het_key``.

    Called with the SAME key every slot of a run (``SchedulerState.het_key``),
    so links stay persistently heterogeneous across slots — resampling these
    from the per-slot key was the bug that erased the capacity skew."""
    two_pi = 2.0 * jnp.pi
    return Heterogeneity(
        link_het=0.5 + _uniform_grid(jax.random.fold_in(het_key, 0), n, m),
        ec_het=0.5 + _uniform_grid(jax.random.fold_in(het_key, 1), m, m),
        phase_d=_uniform_grid(jax.random.fold_in(het_key, 2), n, m, 0.0, two_pi),
        phase_D=_uniform_grid(jax.random.fold_in(het_key, 3), m, m, 0.0, two_pi),
    )


def _traffic(key: jax.Array, phase: jax.Array, t: jax.Array) -> jax.Array:
    """Normalized traffic in [0, 0.95]: diurnal base (slot-invariant
    ``phase`` from :func:`heterogeneity`) + per-slot Beta(2,4) noise."""
    n, m = phase.shape
    # The phase used to be drawn from k1; it now arrives slot-invariant from
    # het_key. The split stays so the k2 noise stream is unchanged.
    _, k2 = jax.random.split(key)
    diurnal = 0.35 + 0.3 * jnp.sin(2 * jnp.pi * t / 288.0 + phase)  # 5-min slots
    noise = _beta_grid(k2, n, m, 2.0, 4.0) * 0.4
    return jnp.clip(diurnal + noise, 0.0, 0.95)


def _workload(key: jax.Array, m: int) -> jax.Array:
    """Normalized co-tenant workload in [0, 0.9] (Beta(2,5): mostly low)."""
    return jnp.clip(_beta_vec(key, m, 2.0, 5.0), 0.0, 0.9)


def sample_network_state(
    key: jax.Array, cfg: CocktailConfig | ShapeConfig, t: jax.Array,
    params: Optional[SliceParams] = None,
    het_key: Optional[jax.Array] = None,
) -> NetworkState:
    """NetworkState for slot t: per-slot noise from ``key``, persistent
    heterogeneity from ``het_key`` (defaults to the seed-0 het key for legacy
    direct callers; production ``step`` passes ``SchedulerState.het_key``)."""
    shape, params = split_config(cfg, params)
    n, m = shape.n_cu, shape.n_ec
    if het_key is None:
        het_key = het_key_from_seed(0)
    het = heterogeneity(het_key, n, m)
    # kh (the old, per-slot heterogeneity key — the bug) stays in the split so
    # the other seven streams keep their historical draws.
    kd, kD, kf, kc, ke, kp, ka, _ = jax.random.split(key, 8)

    # CU-EC capacity: baseline * persistent multiplier * (1 - traffic).
    d = params.d_base * het.link_het * (1.0 - _traffic(kd, het.phase_d, t))

    cap_d = params.cap_d_base * het.ec_het * (1.0 - _traffic(kD, het.phase_D, t))
    cap_d = 0.5 * (cap_d + cap_d.T)
    cap_d = cap_d * (1.0 - jnp.eye(m))

    f = params.f_base * (1.0 - _workload(kf, m))

    # Unit costs: baseline * (1 + U(0,1)) - "dynamics following 0-1 uniform".
    c = params.c_base * (1.0 + _uniform_grid(kc, n, m))
    e = params.e_base * (1.0 + _uniform_grid(ke, m, m))
    e = 0.5 * (e + e.T) * (1.0 - jnp.eye(m))
    p = params.p_base * (1.0 + _uniform_vec(kp, m))

    arrivals = params.zeta * (0.5 + _uniform_vec(ka, n))  # E[A_i] = zeta_i

    # Ragged padding: masked entities have no capacity, generate no data and
    # can do no work; unit costs stay finite (they only ever multiply zeros).
    cu_mask, ec_mask = entity_masks(params)
    link_mask = cu_mask[:, None] * ec_mask[None, :]
    pair_mask = ec_mask[:, None] * ec_mask[None, :]
    return NetworkState(
        d=(d * link_mask).astype(jnp.float32),
        cap_d=(cap_d * pair_mask).astype(jnp.float32),
        f=(f * ec_mask).astype(jnp.float32),
        c=c.astype(jnp.float32),
        e=e.astype(jnp.float32),
        p=p.astype(jnp.float32),
        arrivals=(arrivals * cu_mask).astype(jnp.float32),
    )


def framework_cost(net: NetworkState, collected: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Per-slot framework cost C(t), eq. (14).

    collected[i,j] = alpha*theta*d samples moved CU i -> EC j.
    trained_at[i,k] = x[i,k] + sum_j y[i,j,k].
    """
    trans_cu = jnp.sum(net.c * collected)
    trans_ec = jnp.sum(net.e[None, :, :] * y)  # e[j,k] per sample moved j->k
    trained_at = x + jnp.sum(y, axis=1)  # (N, M): trained at EC k
    compute = jnp.sum(net.p[None, :] * trained_at)
    return trans_cu + trans_ec + compute
