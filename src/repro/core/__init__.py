"""Cocktail core: cost-efficient, data-skew-aware online data scheduling.

Public API:
  CocktailConfig, NetworkState, QueueState, Multipliers, Decision,
  SchedulerState, init_state           -- state types
  sample_network_state, framework_cost -- stochastic environment (Sec. II)
  step, run, AlgoSpec and the named specs (DS, LDS, NO_SDC, ...) -- Sec. III
  metrics                              -- Sec. IV evaluation metrics
"""
from .datasche import (ALL_SPECS, CU_FULL, DS, DS_EXACT, EC_FULL, EC_SELF,
                       GREEDY, LDS, NO_LSA, NO_SDC, NO_SLT, AlgoSpec,
                       SlotRecord, collection_weights, run, skew_degree, step,
                       training_weights)
from .network import framework_cost, sample_network_state
from .types import (CocktailConfig, Decision, Multipliers, NetworkState,
                    QueueState, SchedulerState, init_state)

__all__ = [
    "ALL_SPECS", "AlgoSpec", "CocktailConfig", "CU_FULL", "DS", "DS_EXACT",
    "Decision", "EC_FULL", "EC_SELF", "GREEDY", "LDS", "Multipliers",
    "NetworkState", "NO_LSA", "NO_SDC", "NO_SLT", "QueueState",
    "SchedulerState", "SlotRecord", "collection_weights", "framework_cost",
    "init_state", "run", "sample_network_state", "skew_degree", "step",
    "training_weights",
]
