"""Cocktail core: cost-efficient, data-skew-aware online data scheduling.

Public API:
  CocktailConfig, ShapeConfig, SliceParams, split_config, stack_slice_params,
  NetworkState, QueueState, Multipliers, Decision,
  SchedulerState, init_state           -- state types (batch-first split)
  sample_network_state, framework_cost -- stochastic environment (Sec. II)
  step, run, AlgoSpec and the named specs (DS, LDS, NO_SDC, ...) -- Sec. III
  COLLECTION_POLICIES, TRAINING_POLICIES, PolicyTable, SWITCHED, with_policy
                                       -- indexed policy tables; branch-free
                                          (lax.switch) per-slice dispatch
  SliceJob, FleetEngine.from_jobs      -- K-slice vmapped fleet scheduling:
                                          homogeneous, ragged mixed-shape
                                          (padding + entity masks) and
                                          mixed-policy fleets in ONE program
  metrics                              -- Sec. IV evaluation metrics
"""
from .datasche import (ALL_SPECS, COLLECTION_POLICIES, CU_FULL, DS, DS_EXACT,
                       EC_FULL, EC_SELF, GREEDY, LDS, NO_LSA, NO_SDC, NO_SLT,
                       SWITCHED, SWITCHED_NOAID, TRAINING_POLICIES, AlgoSpec,
                       PolicyTable, SlotRecord, collection_weights, run,
                       skew_degree, stack_slot_records, step, training_weights,
                       with_policy)
from .fleet import FleetEngine, ragged_pad_shape, trim_state
from .job import SliceJob, as_jobs
from .network import framework_cost, sample_network_state
from .types import (MASKED_WEIGHT, CocktailConfig, Decision, Multipliers,
                    NetworkState, QueueState, SchedulerState, ShapeConfig,
                    SliceParams, entity_masks, init_state, mask_pairs,
                    split_config, stack_slice_params)

__all__ = [
    "ALL_SPECS", "AlgoSpec", "CocktailConfig", "COLLECTION_POLICIES",
    "CU_FULL", "DS", "DS_EXACT", "Decision", "EC_FULL", "EC_SELF",
    "FleetEngine", "GREEDY", "LDS", "Multipliers", "NetworkState", "NO_LSA",
    "NO_SDC", "NO_SLT", "PolicyTable", "QueueState", "SWITCHED",
    "SWITCHED_NOAID",
    "SchedulerState", "ShapeConfig", "SliceJob", "SliceParams", "SlotRecord",
    "TRAINING_POLICIES", "MASKED_WEIGHT", "as_jobs", "collection_weights",
    "entity_masks", "framework_cost", "init_state", "mask_pairs",
    "ragged_pad_shape", "run", "sample_network_state", "skew_degree",
    "split_config", "stack_slice_params", "stack_slot_records", "step",
    "training_weights", "trim_state", "with_policy",
]
