"""Evaluation metrics used by the paper's figures (Sec. IV)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .types import CocktailConfig, SchedulerState


def stdev_collection(state: SchedulerState) -> float:
    """Fig. 5 metric: STDEV of cumulative per-CU upload amounts."""
    return float(np.std(np.asarray(state.uploaded)))


def stdev_training_per_ec(state: SchedulerState) -> np.ndarray:
    """Fig. 6 metric: per-EC STDEV of cumulative trained amounts over CUs."""
    return np.std(np.asarray(state.queues.omega), axis=0)


def unit_cost(state: SchedulerState) -> float:
    """Fig. 9 metric: total cost / total trained samples."""
    trained = float(state.total_trained)
    return float(state.total_cost) / max(trained, 1e-9)


def skew_matrix(cfg: CocktailConfig, state: SchedulerState) -> np.ndarray:
    """Per-(CU, EC) signed skew: Omega_ij/sum_l Omega_lj - zeta_i/sum zeta."""
    omega = np.asarray(state.queues.omega, np.float64)
    tot = omega.sum(axis=0, keepdims=True)
    frac = np.divide(omega, np.maximum(tot, 1e-9))
    return frac - cfg.proportions[:, None]


def summary(cfg: CocktailConfig, state: SchedulerState) -> dict:
    t = max(int(state.t), 1)
    return {
        "slots": int(state.t),
        "total_cost": float(state.total_cost),
        "avg_cost": float(state.total_cost) / t,
        "total_trained": float(state.total_trained),
        "unit_cost": unit_cost(state),
        "stdev_collection": stdev_collection(state),
        "stdev_training": [float(v) for v in stdev_training_per_ec(state)],
        "skew_degree": float(np.abs(skew_matrix(cfg, state)).max()),
        "q_backlog": float(np.asarray(state.queues.q).sum()),
        "r_backlog": float(np.asarray(state.queues.r).sum()),
    }
