"""Core pytree types for the Cocktail scheduler.

Notation follows the paper (Section II):
  N CUs (data sources, index i), M ECs (ML workers, index j/k).
  Q[i]      CU data queue backlog (eq. 1)
  R[i,j]    per-CU queue maintained at EC j (eq. 12)
  Omega[i,j] cumulative samples from CU i trained by EC j (eq. 9)
  mu[i], eta[i,j], phi[i,j], lam[i,j]  Lagrange multipliers for (16a)-(16d)

Decisions per slot:
  alpha[i,j] in {0,1}  CU i connected to EC j          (constraint 2)
  theta[i,j] >= 0      connection duration fraction     (constraint 3)
  x[i,j]     >= 0      samples from R[i,j] trained at j (constraint 8,13)
  y[i,j,k]   >= 0      samples from R[i,j] offloaded to and trained at k
                       (constraints 5-8,13)
  z[j,k] in {0,1}      EC j paired with EC k            (constraint 5)

All quantities are in units of one data sample; computing capacity f is in
cycles and rho converts cycles -> samples (F = f / rho samples per slot).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """Static (hashable, trace-time) part of a slice configuration.

    Only fields that determine array shapes or compiled control flow live
    here; everything numeric that can differ between slices of a fleet is a
    runtime ``SliceParams`` leaf. A jit/scan/vmap program is specialised on
    ``ShapeConfig`` + ``AlgoSpec`` alone, so K heterogeneous slices with the
    same shape share one compiled program.
    """

    n_cu: int  # N data sources
    n_ec: int  # M ML workers
    pair_iters: int = 120  # pair-allocation solver iterations (PGA)


class SliceParams(NamedTuple):
    """Runtime (traced, vmappable) per-slice parameters.

    Every leaf is a jnp array so a fleet of K slices is just this pytree with
    a leading K axis (``stack_slice_params``). Scalars are rank-0 float32.

    ``cu_mask`` / ``ec_mask`` support ragged fleets: a slice whose true shape
    is smaller than the compiled ``ShapeConfig`` is zero-padded, with masks
    marking the real entities (1.0) vs the padding (0.0). Masked entities get
    zero capacity/arrivals and -inf solver weights, so every policy provably
    ignores them and the padded program reproduces the unpadded one on the
    real block. ``from_config`` emits all-ones masks, so existing call sites
    are unchanged.

    ``collect_id`` / ``train_id`` / ``use_lsa`` / ``learning_aid`` are the
    *policy leaves* for branch-free dispatch (``datasche.SWITCHED``): the
    algorithm choice itself becomes runtime data, so slices running
    *different* paper variants vmap into one compiled program.
    ``from_config`` defaults them to the DS spec (skew/skew, LSA on, no
    learning aid — ids pinned by an assertion in ``datasche``); fill them
    from any other static ``AlgoSpec`` with ``datasche.with_policy``. The
    Python-static dispatch path ignores them entirely, so existing call
    sites are untouched. Hand-constructed params may leave them None.
    """

    zeta: jax.Array  # (N,) average data generation rate per CU
    proportions: jax.Array  # (N,) zeta / sum(zeta)
    delta_lo: jax.Array  # (N,) \check{delta}_i skew lower bound
    delta_hi: jax.Array  # (N,) \hat{delta}_i skew upper bound
    eps: jax.Array  # () multiplier SGD step size
    rho: jax.Array  # () compute cycles per sample
    q0: jax.Array  # () initial CU queue backlog
    sigma0: jax.Array  # () empirical-multiplier base step (L-DS)
    d_base: jax.Array  # () CU-EC transmission capacity baseline
    cap_d_base: jax.Array  # () EC-EC transmission capacity baseline
    f_base: jax.Array  # (M,) EC computing capacity baseline (cycles)
    c_base: jax.Array  # () unit CU->EC transmission cost
    e_base: jax.Array  # () unit EC<->EC transmission cost
    p_base: jax.Array  # () unit computing cost
    cu_mask: jax.Array = None  # (N,) 1.0 = real CU, 0.0 = ragged padding
    ec_mask: jax.Array = None  # (M,) 1.0 = real EC, 0.0 = ragged padding
    # Policy leaves (branch-free dispatch; see datasche.with_policy/SWITCHED).
    collect_id: jax.Array = None  # () int32 index into COLLECTION_POLICIES
    train_id: jax.Array = None  # () int32 index into TRAINING_POLICIES
    use_lsa: jax.Array = None  # () float32 {0,1} long-term skew amendment on
    learning_aid: jax.Array = None  # () float32 {0,1} L-DS virtual updates on

    @classmethod
    def from_config(cls, cfg: "CocktailConfig",
                    pad_shape: "Optional[ShapeConfig]" = None) -> "SliceParams":
        """Build params for ``cfg``; with ``pad_shape`` the entity axes are
        zero-padded to (pad_shape.n_cu, pad_shape.n_ec) and the masks mark the
        real block, so the slice can join a ragged fleet compiled at the pad
        shape."""
        f32 = lambda v: jnp.asarray(v, jnp.float32)
        n, m = cfg.n_cu, cfg.n_ec
        n_pad = n if pad_shape is None else pad_shape.n_cu
        m_pad = m if pad_shape is None else pad_shape.n_ec
        if n_pad < n or m_pad < m:
            raise ValueError(f"pad shape ({n_pad}, {m_pad}) smaller than "
                             f"true shape ({n}, {m})")
        pad_n = lambda v: jnp.pad(f32(v), (0, n_pad - n))
        pad_m = lambda v: jnp.pad(f32(v), (0, m_pad - m))
        return cls(
            zeta=pad_n(cfg.zeta_vec),
            proportions=pad_n(cfg.proportions),
            delta_lo=pad_n(cfg.delta_lo),
            delta_hi=pad_n(cfg.delta_hi),
            eps=f32(cfg.eps),
            rho=f32(cfg.rho),
            q0=f32(cfg.q0),
            sigma0=f32(cfg.sigma0),
            d_base=f32(cfg.d_base),
            cap_d_base=f32(cfg.cap_d_base),
            f_base=pad_m(jnp.broadcast_to(f32(cfg.f_base), (m,))),
            c_base=f32(cfg.c_base),
            e_base=f32(cfg.e_base),
            p_base=f32(cfg.p_base),
            cu_mask=(jnp.arange(n_pad) < n).astype(jnp.float32),
            ec_mask=(jnp.arange(m_pad) < m).astype(jnp.float32),
            # DS defaults; datasche pins these ids against the policy tables.
            collect_id=jnp.asarray(0, jnp.int32),
            train_id=jnp.asarray(0, jnp.int32),
            use_lsa=jnp.asarray(1.0, jnp.float32),
            learning_aid=jnp.asarray(0.0, jnp.float32),
        )


def entity_masks(params: SliceParams) -> tuple[jax.Array, jax.Array]:
    """(cu_mask (N,), ec_mask (M,)) of a params pytree, defaulting to all-ones
    for params built before the mask fields existed (hand-constructed)."""
    cu = params.cu_mask if params.cu_mask is not None else jnp.ones_like(params.zeta)
    ec = params.ec_mask if params.ec_mask is not None else jnp.ones_like(params.f_base)
    return cu, ec


# Weight of anything touching a ragged-padded entity: large negative so no
# greedy/knapsack/waterfill policy ever selects it, but finite so products
# with the (exactly zero) padded allocations stay 0 instead of NaN.
MASKED_WEIGHT = -1e30


def mask_pairs(a: jax.Array, row_mask: jax.Array, col_mask: jax.Array,
               fill: float = MASKED_WEIGHT) -> jax.Array:
    """Force entries of a (..., R, C) array whose row or column entity is
    masked to ``fill`` — the one place the ragged-padding mask product is
    spelled out (weights use MASKED_WEIGHT, capacities use 0)."""
    return jnp.where((row_mask[..., :, None] * col_mask[..., None, :]) > 0, a, fill)


# fold_in salt separating the slot-invariant heterogeneity key from every
# other use of the run seed (arbitrary constant, spells "HET\0").
_HET_FOLD = 0x48455400


def het_key_from_seed(seed: int | jax.Array) -> jax.Array:
    """The slot-invariant PRNG key driving *persistent* network heterogeneity
    (per-link capacity multipliers + diurnal phases, ``network.heterogeneity``).

    Derived once from the run seed and carried unchanged in
    ``SchedulerState.het_key``, so the capacity skew the scheduler fights
    persists across slots instead of being resampled i.i.d. every slot."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), _HET_FOLD)


def stack_slice_params(params: list["SliceParams"] | tuple["SliceParams", ...]) -> "SliceParams":
    """Stack K per-slice parameter pytrees into one (K, ...) pytree."""
    return jax.tree.map(lambda *ls: jnp.stack(ls), *params)


@dataclasses.dataclass(frozen=True)
class CocktailConfig:
    """Static configuration of one Cocktail network slice (one training job).

    User-facing frontend; the core operates on the ``shape`` / ``params``
    split (``split_config``) so runtime parameters stay traced and batchable.
    """

    n_cu: int  # N data sources
    n_ec: int  # M ML workers
    delta: float = 0.02  # long-term skew tolerance (eq. 9)
    eps: float = 0.1  # multiplier SGD step size
    rho: float = 1.0  # compute cycles per sample (f/rho = samples/slot)
    q0: float = 5000.0  # initial CU queue backlog (paper: "sufficient")
    # Average data generation rate per CU; scalar -> uniform.
    zeta: float | np.ndarray = 500.0
    # Baselines for the stochastic network state (paper Sec. IV-A/IV-C).
    d_base: float = 2000.0  # CU-EC transmission capacity baseline (samples/slot)
    cap_d_base: float = 8000.0  # EC-EC transmission capacity baseline
    f_base: float | np.ndarray = 20000.0  # EC computing capacity baseline (cycles)
    c_base: float = 500.0  # unit CU->EC transmission cost
    e_base: float = 30.0  # unit EC<->EC transmission cost
    p_base: float = 100.0  # unit computing cost
    # Learning-aid parameters.
    sigma0: float = 1.0  # empirical multiplier base step (diminishing sigma0/sqrt t)
    # Pair-allocation solver iterations (projected gradient ascent).
    pair_iters: int = 120
    seed: int = 0

    @property
    def zeta_vec(self) -> np.ndarray:
        z = np.asarray(self.zeta, dtype=np.float64)
        if z.ndim == 0:
            z = np.full((self.n_cu,), float(z))
        assert z.shape == (self.n_cu,)
        return z

    @property
    def proportions(self) -> np.ndarray:
        z = self.zeta_vec
        return z / z.sum()

    @property
    def delta_lo(self) -> np.ndarray:  # \check{delta}_i
        return np.maximum(self.proportions - self.delta, 0.0)

    @property
    def delta_hi(self) -> np.ndarray:  # \hat{delta}_i
        return np.minimum(self.proportions + self.delta, 1.0)

    @property
    def shape(self) -> ShapeConfig:
        return ShapeConfig(n_cu=self.n_cu, n_ec=self.n_ec, pair_iters=self.pair_iters)

    @property
    def params(self) -> SliceParams:
        return SliceParams.from_config(self)


def split_config(
    cfg: "CocktailConfig | ShapeConfig", params: Optional[SliceParams] = None
) -> tuple[ShapeConfig, SliceParams]:
    """Normalise either a frontend ``CocktailConfig`` or an explicit
    (``ShapeConfig``, ``SliceParams``) pair into the split the core runs on."""
    if isinstance(cfg, CocktailConfig):
        return cfg.shape, (cfg.params if params is None else params)
    if params is None:
        raise TypeError("ShapeConfig requires explicit SliceParams")
    return cfg, params


class NetworkState(NamedTuple):
    """Time-varying network state S(t) plus arrivals A(t) for one slot."""

    d: jax.Array  # (N, M) CU->EC transmission capacity, samples/slot
    cap_d: jax.Array  # (M, M) EC<->EC transmission capacity (symmetric, 0 diag)
    f: jax.Array  # (M,)  EC computing capacity, cycles/slot
    c: jax.Array  # (N, M) unit CU->EC transmission cost
    e: jax.Array  # (M, M) unit EC<->EC transmission cost
    p: jax.Array  # (M,)  unit computing cost
    arrivals: jax.Array  # (N,) generated samples A_i(t)


class Multipliers(NamedTuple):
    mu: jax.Array  # (N,)   queue-stability for Q   (16a)
    eta: jax.Array  # (N, M) queue-stability for R   (16b)
    phi: jax.Array  # (N, M) skew lower bound        (16c)
    lam: jax.Array  # (N, M) skew upper bound        (16d)

    @staticmethod
    def zeros(n_cu: int, n_ec: int, q0: float = 0.0, eps: float = 0.1) -> "Multipliers":
        # mu is initialised consistently with the Q0 backlog (mu = eps * Q).
        return Multipliers(
            mu=jnp.full((n_cu,), q0 * eps, jnp.float32),
            eta=jnp.zeros((n_cu, n_ec), jnp.float32),
            phi=jnp.zeros((n_cu, n_ec), jnp.float32),
            lam=jnp.zeros((n_cu, n_ec), jnp.float32),
        )


class QueueState(NamedTuple):
    q: jax.Array  # (N,)   CU queues
    r: jax.Array  # (N, M) CU queues at ECs
    omega: jax.Array  # (N, M) cumulative trained per (CU, EC)

    @staticmethod
    def init(n_cu: int, n_ec: int, q0: float) -> "QueueState":
        return QueueState(
            q=jnp.full((n_cu,), q0, jnp.float32),
            r=jnp.zeros((n_cu, n_ec), jnp.float32),
            omega=jnp.zeros((n_cu, n_ec), jnp.float32),
        )


class Decision(NamedTuple):
    alpha: jax.Array  # (N, M) {0,1}
    theta: jax.Array  # (N, M) >= 0, sum_i theta[:, j] <= 1
    x: jax.Array  # (N, M) >= 0
    y: jax.Array  # (N, M, M) y[i, j, k]: from R[i,j], trained at k
    z: jax.Array  # (M, M) {0,1} symmetric pairing

    @property
    def duty(self) -> jax.Array:
        """(N, M) duty cycle alpha*theta: fraction of the slot each CU->EC
        connection is live (dimensionless; multiply by capacity d to get
        samples — see :meth:`collected`)."""
        return self.alpha * self.theta

    def collected(self, net: "NetworkState") -> jax.Array:
        """(N, M) samples moved CU->EC this slot: alpha * theta * d, i.e. the
        duty cycle times the slot's transmission capacity. (Not backlog-capped;
        the executed transfer additionally scales by the Q backlog, see
        ``datasche._served``.)"""
        return self.alpha * self.theta * net.d

    @staticmethod
    def zeros(n_cu: int, n_ec: int) -> "Decision":
        return Decision(
            alpha=jnp.zeros((n_cu, n_ec), jnp.float32),
            theta=jnp.zeros((n_cu, n_ec), jnp.float32),
            x=jnp.zeros((n_cu, n_ec), jnp.float32),
            y=jnp.zeros((n_cu, n_ec, n_ec), jnp.float32),
            z=jnp.zeros((n_ec, n_ec), jnp.float32),
        )


class SchedulerState(NamedTuple):
    """Full state carried slot-to-slot by DataSche / L-DS."""

    queues: QueueState
    mults: Multipliers
    emp_mults: Multipliers  # empirical multipliers Theta' (L-DS only; zeros for DS)
    t: jax.Array  # scalar int32 slot counter
    total_cost: jax.Array  # scalar accumulated framework cost
    total_trained: jax.Array  # scalar accumulated |D(t)|
    uploaded: jax.Array  # (N,) cumulative per-CU uploads (Fig. 5 metric)
    rng: jax.Array  # PRNG key for stochastic network state
    # Slot-invariant key for persistent network heterogeneity (het_key_from_
    # seed): step threads it through sample_network_state UNCHANGED, so the
    # per-link capacity multipliers and diurnal phases persist across slots
    # while the noise terms (drawn from rng's per-slot splits) stay i.i.d.
    # None on hand-built legacy states -> the sampler's documented default.
    het_key: jax.Array = None


def init_state(
    cfg: "CocktailConfig | ShapeConfig",
    params: Optional[SliceParams] = None,
    seed: Optional[int] = None,
) -> SchedulerState:
    shape, params = split_config(cfg, params)
    if seed is None:
        seed = getattr(cfg, "seed", 0)
    cu_mask, _ = entity_masks(params)
    queues = QueueState.init(shape.n_cu, shape.n_ec, params.q0)
    # Ragged padding: masked CUs carry no backlog and a zero queue price, so
    # scalar records (q_backlog, ...) sum only over real entities.
    queues = queues._replace(q=queues.q * cu_mask)
    mults = Multipliers.zeros(shape.n_cu, shape.n_ec, params.q0, params.eps)
    mults = mults._replace(mu=mults.mu * cu_mask)
    return SchedulerState(
        queues=queues,
        mults=mults,
        emp_mults=mults,
        t=jnp.asarray(0, jnp.int32),
        total_cost=jnp.asarray(0.0, jnp.float32),
        total_trained=jnp.asarray(0.0, jnp.float32),
        uploaded=jnp.zeros((shape.n_cu,), jnp.float32),
        rng=jax.random.PRNGKey(seed),
        het_key=het_key_from_seed(seed),
    )
