"""Per-slot training-allocation solvers for subproblem P2' (and linear P2).

Three solvers, all pure JAX / jittable:

* ``solo_waterfill``  — problem (20): max sum_i log(beta_i x_i) with one
  compute budget and per-queue caps. Closed form (capped water-filling via
  sort + cumsum).
* ``pair_allocate``   — problem (21): the two-EC convex program. Solved by
  dual subgradient on the three resource constraints (link D_jk, compute F_j,
  F_k) with an inner closed-form coordinate-ascent primal per CU (the caps
  x_ij + y_ijk <= R_ij couple only variables of the *same* CU, so the inner
  problem is separable over i). A final downscaling pass guarantees exact
  feasibility. The paper's testbed used AMPL+IPOPT here; this is the
  TPU-native, fixed-iteration-count replacement (oracle-checked in tests).
* ``linear_*``        — the non-log (plain P2) variants used by L-DS step 3
  and the NO-SLT ablation: fractional-knapsack greedy fills.

Conventions: compute budgets F are in samples/slot (f/rho); a term only
contributes log(u) to an edge weight when u > 0 — allocating nothing to a
source is always feasible and contributes 0 (matches the paper's implicit
restriction to positively-weighted sources; log of a non-positive allocation
is undefined).

Batch-first: every solver here is shape-polymorphic pure JAX over (N,)
vectors — budgets, caps, and weights may all be traced ``SliceParams``-derived
values, and the whole module vmaps transparently over a leading fleet slice
axis (no Python branching on data anywhere).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

_TINY = 1e-9


def solo_waterfill(beta: jax.Array, r: jax.Array, budget: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Problem (20). Returns (x, objective value).

    max sum_{i active} log(beta_i x_i)  s.t. sum x <= budget, 0 <= x_i <= r_i,
    active = {beta_i > 0, r_i > 0}. Optimal x_i = min(r_i, w) with the water
    level w chosen to exhaust min(budget, sum r_active).
    """
    n = beta.shape[0]
    active = (beta > 0) & (r > _TINY)
    n_act = jnp.sum(active)
    r_act = jnp.where(active, r, 0.0)
    fill = jnp.minimum(jnp.maximum(budget, 0.0), jnp.sum(r_act))

    s = jnp.sort(jnp.where(active, r, jnp.inf))  # ascending; inactive last
    s_fin = jnp.where(jnp.isfinite(s), s, 0.0)
    cs = jnp.concatenate([jnp.zeros((1,), s.dtype), jnp.cumsum(s_fin)])[:-1]  # cs[k] = k smallest
    k = jnp.arange(n)
    denom = jnp.maximum((n_act - k).astype(r.dtype), 1.0)
    w_k = (fill - cs) / denom
    s_prev = jnp.concatenate([jnp.zeros((1,), s.dtype), s])[:-1]
    valid = (k < n_act) & (w_k >= s_prev - 1e-6) & (w_k <= s + 1e-6)
    # If sum r_active <= budget the level is max(r) and k = n_act-1 is valid.
    any_valid = jnp.any(valid)
    k_star = jnp.argmax(valid)  # first valid segment
    level = jnp.where(any_valid, w_k[k_star], 0.0)
    x = jnp.where(active, jnp.minimum(r, jnp.maximum(level, 0.0)), 0.0)
    pos = x > _TINY
    value = jnp.sum(jnp.where(pos, jnp.log(jnp.maximum(beta * x, _TINY)), 0.0))
    return x, value


class PairAlloc(NamedTuple):
    x_j: jax.Array  # (N,) trained at j from R[:, j]
    x_k: jax.Array  # (N,) trained at k from R[:, k]
    y_jk: jax.Array  # (N,) moved j -> k, trained at k
    y_kj: jax.Array  # (N,) moved k -> j, trained at j
    value: jax.Array  # scalar objective


def _coord_ascent_pair(
    duals: jax.Array,
    b_j: jax.Array, g_kj: jax.Array, b_k: jax.Array, g_jk: jax.Array,
    r_j: jax.Array, r_k: jax.Array,
    sweeps: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Closed-form cyclic coordinate ascent for the per-CU subproblem given
    resource prices (a, m_j, m_k): maximize
        log(b_j x_j + g_kj y_kj) + log(b_k x_k + g_jk y_jk)
        - m_j (x_j + y_kj) - m_k (x_k + y_jk) - a (y_jk + y_kj)
    s.t. x_j + y_jk <= r_j,  x_k + y_kj <= r_k,  vars >= 0.

    Each coordinate update of max log(b v + c) - p v with v <= cap is
    v* = clip(1/p - c/b, 0, cap).
    """
    a, m_j, m_k = duals[0], duals[1], duals[2]
    p_xj, p_ykj = m_j + _TINY, m_j + a + _TINY
    p_xk, p_yjk = m_k + _TINY, m_k + a + _TINY

    def upd(w, p, c, cap):
        v = jnp.where(w > 0, 1.0 / p - c / jnp.maximum(w, _TINY), 0.0)
        return jnp.clip(v, 0.0, jnp.maximum(cap, 0.0))

    def sweep(_, vs):
        x_j, y_kj, x_k, y_jk = vs
        x_j = upd(b_j, p_xj, g_kj * y_kj, r_j - y_jk)
        x_k = upd(b_k, p_xk, g_jk * y_jk, r_k - y_kj)
        y_kj = upd(g_kj, p_ykj, b_j * x_j, r_k - x_k)
        y_jk = upd(g_jk, p_yjk, b_k * x_k, r_j - x_j)
        return x_j, y_kj, x_k, y_jk

    zeros = jnp.zeros_like(r_j)
    return jax.lax.fori_loop(0, sweeps, sweep, (zeros, zeros, zeros, zeros))


def pair_allocate(
    b_j: jax.Array, g_kj: jax.Array, b_k: jax.Array, g_jk: jax.Array,
    r_j: jax.Array, r_k: jax.Array,
    budget_j: jax.Array, budget_k: jax.Array, link: jax.Array,
    iters: int = 60, sweeps: int = 4,
) -> PairAlloc:
    """Problem (21) for a pair (j, k) of ECs. All vector args are (N,)."""
    cap = jnp.stack([link, budget_j, budget_k])
    cap = jnp.maximum(cap, 0.0)

    def dual_step(t, duals):
        x_j, y_kj, x_k, y_jk = _coord_ascent_pair(duals, b_j, g_kj, b_k, g_jk, r_j, r_k, sweeps)
        use = jnp.stack([
            jnp.sum(y_jk + y_kj),
            jnp.sum(x_j + y_kj),
            jnp.sum(x_k + y_jk),
        ])
        grad = (use - cap) / (cap + 1.0)
        step = 0.5 / jnp.sqrt(t + 1.0)
        return jnp.maximum(duals + step * grad, 0.0)

    duals0 = jnp.ones((3,), jnp.float32) * 0.01
    duals = jax.lax.fori_loop(0, iters, dual_step, duals0)
    x_j, y_kj, x_k, y_jk = _coord_ascent_pair(duals, b_j, g_kj, b_k, g_jk, r_j, r_k, sweeps)

    # Exact feasibility: scale queue caps per-CU, then global resources.
    s_j = jnp.minimum(1.0, r_j / jnp.maximum(x_j + y_jk, _TINY))
    x_j, y_jk = x_j * s_j, y_jk * s_j
    s_k = jnp.minimum(1.0, r_k / jnp.maximum(x_k + y_kj, _TINY))
    x_k, y_kj = x_k * s_k, y_kj * s_k
    s_fj = jnp.minimum(1.0, cap[1] / jnp.maximum(jnp.sum(x_j + y_kj), _TINY))
    x_j, y_kj = x_j * s_fj, y_kj * s_fj
    s_fk = jnp.minimum(1.0, cap[2] / jnp.maximum(jnp.sum(x_k + y_jk), _TINY))
    x_k, y_jk = x_k * s_fk, y_jk * s_fk
    s_l = jnp.minimum(1.0, cap[0] / jnp.maximum(jnp.sum(y_jk + y_kj), _TINY))
    y_jk, y_kj = y_jk * s_l, y_kj * s_l

    u_j = b_j * x_j + g_kj * y_kj
    u_k = b_k * x_k + g_jk * y_jk
    value = jnp.sum(jnp.where(u_j > _TINY, jnp.log(jnp.maximum(u_j, _TINY)), 0.0))
    value += jnp.sum(jnp.where(u_k > _TINY, jnp.log(jnp.maximum(u_k, _TINY)), 0.0))
    return PairAlloc(x_j=x_j, x_k=x_k, y_jk=y_jk, y_kj=y_kj, value=value)


def linear_solo(beta: jax.Array, r: jax.Array, budget: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Plain-P2 solo: max sum beta_i x_i (linear). Fractional knapsack —
    fill caps in descending beta order. Exact. Returns (x, value)."""
    active = (beta > 0) & (r > _TINY)
    order = jnp.argsort(jnp.where(active, -beta, jnp.inf))
    r_ord = jnp.where(active, r, 0.0)[order]
    cs = jnp.concatenate([jnp.zeros((1,), r.dtype), jnp.cumsum(r_ord)])[:-1]
    alloc_ord = jnp.clip(jnp.maximum(budget, 0.0) - cs, 0.0, r_ord)
    x = jnp.zeros_like(r).at[order].set(alloc_ord)
    x = jnp.where(active, x, 0.0)
    return x, jnp.sum(beta * x)


def linear_pair(
    b_j: jax.Array, g_kj: jax.Array, b_k: jax.Array, g_jk: jax.Array,
    r_j: jax.Array, r_k: jax.Array,
    budget_j: jax.Array, budget_k: jax.Array, link: jax.Array,
) -> PairAlloc:
    """Plain-P2 pair: greedy fractional fill by descending linear weight over
    the 4N (variable, CU) slots; respects caps + the three resources. A
    0.5-class greedy for the multi-resource LP (documented approximation)."""
    n = b_j.shape[0]
    # var layout: [x_j | y_kj | x_k | y_jk] each (N,)
    weights = jnp.concatenate([b_j, g_kj, b_k, g_jk])
    order = jnp.argsort(-weights)

    def body(s, carry):
        rem_rj, rem_rk, rem_fj, rem_fk, rem_d, out = carry
        v = order[s]
        kind, i = v // n, v % n
        w = weights[v]
        # resource draw per kind: (queue, compute, link)
        q_rem = jnp.where((kind == 0) | (kind == 3), rem_rj[i], rem_rk[i])
        f_rem = jnp.where((kind == 0) | (kind == 1), rem_fj, rem_fk)
        l_rem = jnp.where((kind == 1) | (kind == 3), rem_d, jnp.inf)
        amt = jnp.where(w > 0, jnp.minimum(jnp.minimum(q_rem, f_rem), l_rem), 0.0)
        amt = jnp.maximum(amt, 0.0)
        dq_j = jnp.where((kind == 0) | (kind == 3), amt, 0.0)
        dq_k = jnp.where((kind == 1) | (kind == 2), amt, 0.0)
        rem_rj = rem_rj.at[i].add(-dq_j)
        rem_rk = rem_rk.at[i].add(-dq_k)
        rem_fj = rem_fj - jnp.where((kind == 0) | (kind == 1), amt, 0.0)
        rem_fk = rem_fk - jnp.where((kind == 2) | (kind == 3), amt, 0.0)
        rem_d = rem_d - jnp.where((kind == 1) | (kind == 3), amt, 0.0)
        out = out.at[v].set(amt)
        return rem_rj, rem_rk, rem_fj, rem_fk, rem_d, out

    carry = (r_j, r_k, jnp.maximum(budget_j, 0.0), jnp.maximum(budget_k, 0.0),
             jnp.maximum(link, 0.0), jnp.zeros((4 * n,), r_j.dtype))
    *_, out = jax.lax.fori_loop(0, 4 * n, body, carry)
    x_j, y_kj, x_k, y_jk = out[:n], out[n:2 * n], out[2 * n:3 * n], out[3 * n:]
    value = jnp.sum(b_j * x_j + g_kj * y_kj + b_k * x_k + g_jk * y_jk)
    return PairAlloc(x_j=x_j, x_k=x_k, y_jk=y_jk, y_kj=y_kj, value=value)


def full_allocate(
    beta: jax.Array,  # (N, M) weight of x[i, j]
    gamma: jax.Array,  # (N, M, M) weight of y[i, j, k]
    r: jax.Array,  # (N, M) queue caps
    budgets: jax.Array,  # (M,) compute budgets (samples)
    links: jax.Array,  # (M, M) link capacities
    iters: int = 40, sweeps: int = 2,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """ECFull baseline: joint allocation with all EC pairs connected
    (constraint (5) removed). gamma[i, j, k] weights y[i, j, k] (from queue
    R[i,j], trained at k). Dual subgradient on compute (M) + link (M, M)
    constraints, inner coordinate ascent, final downscale. Returns
    (x (N,M), y (N,M,M), value)."""
    n, m = beta.shape
    eye = jnp.eye(m, dtype=bool)

    def primal(duals):
        m_dual, a_dual = duals  # (M,), (M, M) symmetric
        p_x = m_dual[None, :] + _TINY  # price of x[i, j]
        # price of y[i, j, k]: compute at k + link (j,k)
        p_y = m_dual[None, None, :] + a_dual[None, :, :] + _TINY

        def sweep(_, vs):
            x, y = vs
            # u[i, k] = beta*x + sum_j gamma[i,j,k] y[i,j,k]
            u_from_y = jnp.einsum("ijk,ijk->ik", gamma, y)
            # update x: max log(beta x + c) - p x, cap r - sum_k y[i,j,k]
            cap_x = jnp.maximum(r - jnp.sum(y, axis=2), 0.0)
            x = jnp.where(
                beta > 0,
                jnp.clip(1.0 / p_x - u_from_y / jnp.maximum(beta, _TINY), 0.0, cap_x),
                0.0,
            )
            # update y jointly per (j, k): treat each y[:, j, k] given others
            def upd_pair(jk, y):
                j, k = jk // m, jk % m
                u_k = beta[:, k] * x[:, k] + jnp.einsum("ij,ij->i", gamma[:, :, k], y[:, :, k])
                c = u_k - gamma[:, j, k] * y[:, j, k]
                cap = jnp.maximum(r[:, j] - x[:, j] - (jnp.sum(y[:, j, :], axis=1) - y[:, j, k]), 0.0)
                g = gamma[:, j, k]
                v = jnp.where((g > 0) & (j != k), jnp.clip(1.0 / p_y[:, j, k] - c / jnp.maximum(g, _TINY), 0.0, cap), 0.0)
                return y.at[:, j, k].set(v)

            y = jax.lax.fori_loop(0, m * m, upd_pair, y)
            return x, y

        return jax.lax.fori_loop(0, sweeps, sweep,
                                 (jnp.zeros_like(beta), jnp.zeros_like(gamma)))

    def dual_step(t, duals):
        m_dual, a_dual = duals
        x, y = primal(duals)
        trained_at = jnp.sum(x, axis=0) + jnp.einsum("ijk->k", y)
        g_m = (trained_at - budgets) / (budgets + 1.0)
        flow = jnp.einsum("ijk->jk", y)
        flow = flow + flow.T
        g_a = (flow - links) / (links + 1.0)
        g_a = jnp.where(eye, 0.0, g_a)
        step = 0.5 / jnp.sqrt(t + 1.0)
        return (jnp.maximum(m_dual + step * g_m, 0.0),
                jnp.maximum(a_dual + step * g_a, 0.0))

    duals = (jnp.full((m,), 0.01, jnp.float32), jnp.full((m, m), 0.01, jnp.float32))
    duals = jax.lax.fori_loop(0, iters, dual_step, duals)
    x, y = primal(duals)

    # Feasibility: queue caps, then compute, then links (downscaling only).
    dep = x + jnp.sum(y, axis=2)
    s_q = jnp.minimum(1.0, r / jnp.maximum(dep, _TINY))
    x = x * s_q
    y = y * s_q[:, :, None]
    trained_at = jnp.sum(x, axis=0) + jnp.einsum("ijk->k", y)
    s_f = jnp.minimum(1.0, budgets / jnp.maximum(trained_at, _TINY))
    x = x * s_f[None, :]
    y = y * s_f[None, None, :]
    flow = jnp.einsum("ijk->jk", y)
    sym_flow = flow + flow.T
    s_l = jnp.minimum(1.0, links / jnp.maximum(sym_flow, _TINY))
    s_l = jnp.where(eye, 1.0, s_l)
    y = y * s_l[None, :, :]

    u = beta * x + jnp.einsum("ijk,ijk->ik", gamma, y)
    value = jnp.sum(jnp.where(u > _TINY, jnp.log(jnp.maximum(u, _TINY)), 0.0))
    return x, y, value
