"""Matching primitives for the two per-slot subproblems — re-export shim.

The jnp reference implementations moved to ``repro.kernels.matching.ref`` so
the kernel package owns the production semantics (the Pallas kernels are
tested bit-exact against them) and the dependency points core -> kernels.
This module keeps the historical ``repro.core.matching`` names importable.

Production call sites should go through the dispatch layer
``repro.kernels.matching.ops`` (Pallas on TPU, these refs elsewhere,
batch-compatible and mask-aware); exact oracles for the Thm.-1 / Thm.-2
graph constructions live in ``repro.core.oracle``.
"""
from __future__ import annotations

from repro.kernels.matching.ref import (  # noqa: F401
    _marginal_penalty,
    greedy_assignment_ref as greedy_assignment,
    greedy_collection_ref as greedy_collection,
    greedy_pairing_ref as greedy_pairing,
)

_NEG = -1e30

__all__ = ["greedy_collection", "greedy_assignment", "greedy_pairing"]
