"""Fleet engine: one compiled program scheduling K network slices at once.

A real 5G operator runs many concurrent incremental-learning jobs — one
traffic-prediction slice per region, one per tenant — not the single slice of
the paper's testbed. The batch-first core makes this a pure data-parallel
problem: all per-slice numbers live in a ``SliceParams`` pytree, so a fleet
is just that pytree with a leading K axis, and one slot of the whole fleet is
``jax.vmap(step)`` over (params, state). The slot loop is a single
``lax.scan``; the result is ONE jitted program for K heterogeneous slices.

Axis conventions (documented in ROADMAP.md):
  * stacked ``SliceParams`` / ``SchedulerState``: leading axis = slice (K)
  * stacked ``SlotRecord`` returned by :meth:`FleetEngine.run`: time-major
    (T, K) — axis 0 is the slot, matching single-slice ``run``'s (T,)
  * optional device sharding splits the K axis over a mesh axis via
    ``launch.mesh.shard_leading_axis`` (NamedSharding, trailing axes
    replicated)

Constraints: all slices of a fleet run at one *compiled* ``ShapeConfig`` (N,
M and solver iteration counts are compile-time); ``exact`` specs are
host-side and cannot be vmapped. Everything else is transparent through the
:meth:`FleetEngine.from_jobs` frontend (a list of ``SliceJob``):

  * slices with different *true* (N, M) are zero-padded to the
    elementwise-max shape, with the ``SliceParams`` entity masks
    (``cu_mask``/``ec_mask``) making every policy ignore the padding, so the
    padded slice reproduces its standalone run on the real block
    (tests/test_ragged_fleet.py);
  * slices with different ``AlgoSpec`` run under branch-free (``SWITCHED``)
    dispatch: the policy choice is ``lax.switch`` over the indexed policy
    tables, driven by the per-slice policy leaves ``with_policy`` fills —
    still ONE compiled program (tests/test_policy_switch.py).

``from_configs`` / ``from_ragged_configs`` are kept as thin shims over
``from_jobs`` for older call sites.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .datasche import AlgoSpec, DS, SWITCHED, SWITCHED_NOAID, SlotRecord, step
from .job import JobLike, SliceJob, as_jobs
from .types import (CocktailConfig, Decision, Multipliers, QueueState,
                    SchedulerState, ShapeConfig, SliceParams, init_state,
                    split_config, stack_slice_params)


def unstack(tree, k: int):
    """Extract slice k from a stacked (K, ...) pytree (state, params)."""
    return jax.tree.map(lambda l: l[k], tree)


def slice_records(recs: SlotRecord, k: int) -> SlotRecord:
    """Slice k's (T,) per-slot trace out of time-major (T, K) fleet records."""
    return jax.tree.map(lambda l: l[:, k], recs)


def ragged_pad_shape(shapes: Sequence[ShapeConfig]) -> ShapeConfig:
    """The common compiled shape of a ragged fleet: elementwise max over the
    entity axes. Solver iteration counts are control flow, not padding, so
    they must agree across slices."""
    iters = {s.pair_iters for s in shapes}
    if len(iters) != 1:
        raise ValueError(f"ragged fleet slices must share pair_iters, got {iters}")
    return ShapeConfig(n_cu=max(s.n_cu for s in shapes),
                      n_ec=max(s.n_ec for s in shapes),
                      pair_iters=iters.pop())


def trim_state(state: SchedulerState, shape: ShapeConfig) -> SchedulerState:
    """Drop the ragged padding of one slice's state: slice every entity axis
    down to the true (N, M). Padded entries are exactly zero by the mask
    invariants, so this is lossless."""
    n, m = shape.n_cu, shape.n_ec

    def trim_mults(mu: Multipliers) -> Multipliers:
        return Multipliers(mu=mu.mu[:n], eta=mu.eta[:n, :m],
                           phi=mu.phi[:n, :m], lam=mu.lam[:n, :m])

    return state._replace(
        queues=QueueState(q=state.queues.q[:n], r=state.queues.r[:n, :m],
                          omega=state.queues.omega[:n, :m]),
        mults=trim_mults(state.mults),
        emp_mults=trim_mults(state.emp_mults),
        uploaded=state.uploaded[:n],
    )


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _fleet_scan(shape: ShapeConfig, spec: AlgoSpec, n_slots: int,
                params: SliceParams, state: SchedulerState
                ) -> tuple[SchedulerState, SlotRecord]:
    def one_slot(p, s):
        s2, rec, _ = step(shape, spec, s, params=p)
        return s2, rec

    vstep = jax.vmap(one_slot)

    def body(s, _):
        s2, rec = vstep(params, s)
        return s2, rec

    return jax.lax.scan(body, state, None, length=n_slots)


def _stacked_slice_count(params: SliceParams) -> int:
    """K of a stacked (K, ...) params pytree, validating that every non-None
    leaf agrees on the leading (slice) axis. Raises naming the offending leaf
    instead of silently mis-reading an unstacked pytree."""
    k: Optional[int] = None
    first = None
    for name, leaf in zip(SliceParams._fields, params):
        if leaf is None:
            continue
        if jnp.ndim(leaf) == 0:
            raise ValueError(
                f"SliceParams leaf {name!r} is rank-0: params look unstacked "
                "(no leading slice axis); stack K slices with "
                "stack_slice_params first")
        n = jnp.shape(leaf)[0]
        if k is None:
            k, first = int(n), name
        elif n != k:
            raise ValueError(
                f"inconsistent leading (slice) axis across SliceParams leaves: "
                f"{first!r} has K={k} but {name!r} has K={n}")
    if k is None:
        raise ValueError("SliceParams has no array leaves (every field is "
                         "None); build it with SliceParams.from_config / "
                         "stack_slice_params")
    return k


@dataclasses.dataclass(frozen=True)
class FleetEngine:
    """K-slice batch scheduler: vmapped ``step`` inside one jitted scan.

    Build with :meth:`from_jobs` (a list of ``SliceJob`` — handles
    homogeneous, ragged-shape and mixed-policy fleets uniformly), or adopt a
    pre-stacked ``SliceParams`` pytree via :meth:`from_params`.
    """

    shape: ShapeConfig
    spec: AlgoSpec
    params: SliceParams  # stacked, leading axis K
    n_slices: int
    seeds: tuple[int, ...]
    # Per-slice *true* shapes (== (shape,) * K for non-ragged fleets). Only
    # metadata: used by slice_state to trim the padding back off.
    slice_shapes: Optional[tuple[ShapeConfig, ...]] = None
    # Per-slice AlgoSpec (metadata; the compiled program runs self.spec,
    # which is SWITCHED for mixed-policy fleets).
    slice_specs: Optional[tuple[AlgoSpec, ...]] = None

    def __post_init__(self):
        if self.spec.exact:
            raise ValueError("exact (host-side oracle) specs cannot be vmapped; "
                             "use datasche.run per slice instead")

    @classmethod
    def from_jobs(cls, jobs: Sequence[JobLike],
                  spec: AlgoSpec = DS) -> "FleetEngine":
        """THE fleet constructor: one ``SliceJob`` per slice.

        Transparently composes every supported axis of heterogeneity:
        numeric params always differ freely; mixed true (N, M) are padded to
        the elementwise-max shape with entity masks; mixed ``AlgoSpec`` run
        under branch-free ``SWITCHED`` dispatch (policy leaves +
        ``lax.switch``), so the whole fleet is still ONE compiled program.
        Bare ``CocktailConfig`` entries are accepted and get ``spec``.
        """
        jobs = as_jobs(jobs, spec)
        if not jobs:
            raise ValueError("need at least one SliceJob")
        pad = ragged_pad_shape([j.shape for j in jobs])
        policies = {(j.spec.collection, j.spec.training, j.spec.use_lsa,
                     j.spec.learning_aid) for j in jobs}
        # Distinct specs with identical policy tuples (e.g. DS vs GREEDY)
        # still compile one static program — switch only when policies differ.
        # The policy leaves are filled either way, so the params always state
        # what each slice runs (static dispatch just ignores them). Mixed
        # fleets without an L-DS slice get the virtual path compiled out.
        mixed = len(policies) > 1
        any_aid = any(j.spec.learning_aid for j in jobs)
        switch_spec = SWITCHED if any_aid else SWITCHED_NOAID
        return cls(
            shape=pad,
            spec=switch_spec if mixed else jobs[0].spec,
            params=stack_slice_params(
                [j.params(pad_shape=pad, policy_leaves=True) for j in jobs]),
            n_slices=len(jobs),
            seeds=tuple(j.resolved_seed for j in jobs),
            slice_shapes=tuple(j.shape for j in jobs),
            slice_specs=tuple(j.spec for j in jobs),
        )

    @classmethod
    def from_configs(cls, configs: Sequence[CocktailConfig],
                     spec: AlgoSpec = DS) -> "FleetEngine":
        """Deprecated shim over :meth:`from_jobs` (kept for older call sites;
        it still *rejects* mixed shapes, which from_jobs would pad)."""
        if not configs:
            raise ValueError("need at least one slice config")
        shapes = {c.shape for c in configs}
        if len(shapes) != 1:
            raise ValueError(f"fleet slices must share one ShapeConfig, got {shapes}; "
                             "pad mixed shapes with from_jobs/from_ragged_configs")
        return cls.from_jobs([SliceJob(config=c, spec=spec) for c in configs])

    @classmethod
    def from_ragged_configs(cls, configs: Sequence[CocktailConfig],
                            spec: AlgoSpec = DS) -> "FleetEngine":
        """Deprecated shim over :meth:`from_jobs`: batch slices of different
        true (N, M) into one compiled program via padding + entity masks."""
        return cls.from_jobs([SliceJob(config=c, spec=spec) for c in configs])

    @classmethod
    def from_params(cls, shape: ShapeConfig, params: SliceParams,
                    spec: AlgoSpec = DS,
                    seeds: Optional[Sequence[int]] = None) -> "FleetEngine":
        """Adopt an already-stacked (K, ...) SliceParams pytree."""
        k = _stacked_slice_count(params)
        seeds = tuple(seeds) if seeds is not None else tuple(range(k))
        if len(seeds) != k:
            raise ValueError(f"{k} slices but {len(seeds)} seeds")
        return cls(shape=shape, spec=spec, params=params, n_slices=k, seeds=seeds)

    # -- state ------------------------------------------------------------

    def init(self) -> SchedulerState:
        """Stacked initial state: slice k gets params[k] and PRNGKey(seeds[k])."""
        states = [init_state(self.shape, unstack(self.params, k), seed=self.seeds[k])
                  for k in range(self.n_slices)]
        return jax.tree.map(lambda *ls: jnp.stack(ls), *states)

    def slice_state(self, state: SchedulerState, k: int) -> SchedulerState:
        """Slice k's SchedulerState (for per-slice metrics.summary etc.).

        Ragged fleets: the padding is trimmed back off, so the result has the
        slice's true (N, M) and drops straight into shape-aware consumers
        (metrics.summary against the original CocktailConfig)."""
        sk = unstack(state, k)
        if self.slice_shapes is not None and self.slice_shapes[k] != self.shape:
            sk = trim_state(sk, self.slice_shapes[k])
        return sk

    # -- execution --------------------------------------------------------

    def step(self, state: SchedulerState
             ) -> tuple[SchedulerState, SlotRecord, Decision]:
        """One fleet slot (eager vmap; prefer :meth:`run` for loops)."""
        new_state, rec, dec = jax.vmap(
            lambda p, s: step(self.shape, self.spec, s, params=p)
        )(self.params, state)
        return new_state, rec, dec

    def run(self, n_slots: int, state: Optional[SchedulerState] = None,
            mesh=None, axis_name: str = "data"
            ) -> tuple[SchedulerState, SlotRecord]:
        """Run the whole fleet for n_slots inside one jitted scan.

        Returns (stacked final state (K, ...), stacked records (T, K)).
        With ``mesh``, the K axis of params/state is sharded over
        ``mesh[axis_name]`` before the scan (K % axis size must be 0) and XLA
        partitions every slot across devices.
        """
        if state is None:
            state = self.init()
        params = self.params
        if mesh is not None:
            from ..launch.mesh import shard_leading_axis
            params = shard_leading_axis(params, mesh, axis_name)
            state = shard_leading_axis(state, mesh, axis_name)
        return _fleet_scan(self.shape, self.spec, n_slots, params, state)
