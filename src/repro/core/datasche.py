"""DataSche and Learning-aid DataSche online scheduling algorithms (Sec. III).

The per-slot pipeline is

  1. observe network state S(t) (or sample the stochastic generator),
  2. solve the collection subproblem  -> alpha, theta      (P1' / P1 / full)
  3. solve the training subproblem    -> x, y, z           (P2' / linear / ...)
  4. execute: update queues Q, R, cumulative Omega, framework cost,
  5. SGD-update the Lagrange multipliers (step eps); L-DS additionally keeps
     empirical multipliers Theta' updated from *virtual* plain-P1/P2 decisions
     with a diminishing step and schedules with Theta~ = Theta + Theta' - pi.

Policies are selected by an ``AlgoSpec`` so every paper baseline (NO-SDC,
NO-SLT, NO-LSA, Greedy, ECFull, ECSelf, CUFull) is a one-line variant.
``exact=False`` (production) is fully jittable and driven by ``lax.scan``;
``exact=True`` swaps the greedy matchers for the networkx Thm.-1/Thm.-2
oracles and runs a host loop.

Policy dispatch runs off two indexed registries, ``COLLECTION_POLICIES`` and
``TRAINING_POLICIES`` (see ``PolicyTable``), in one of two modes: Python-static
(table lookup by ``spec.collection``/``spec.training`` at trace time) or
branch-free (``SWITCHED`` spec: ``jax.lax.switch`` over the table indexed by
the ``SliceParams`` policy leaves, filled by ``with_policy``). The branch-free
mode is what lets a fleet mix *different* algorithms per slice inside one
compiled program (``fleet.FleetEngine.from_jobs``).

Batch-first convention: everything numeric that can differ between network
slices lives in a ``SliceParams`` pytree (traced), while shapes and control
flow live in the hashable ``ShapeConfig`` (static). ``step``/``run`` accept
either the frontend ``CocktailConfig`` or an explicit split; a fleet of K
slices is ``jax.vmap`` of ``step`` over stacked params/state (see
``repro.core.fleet``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import training_alloc
# Production matching goes through the kernels dispatch layer: Pallas on TPU,
# identical jnp refs elsewhere, batch-compatible and mask-aware. No cycle:
# kernels/matching depends only on core.types.
from ..kernels.matching import ops as matching_ops
from .network import framework_cost, sample_network_state
from .types import (MASKED_WEIGHT, CocktailConfig, Decision, Multipliers,
                    NetworkState, QueueState, SchedulerState, ShapeConfig,
                    SliceParams, entity_masks, init_state, mask_pairs,
                    split_config)

_TINY = 1e-9
_NEG = MASKED_WEIGHT  # masked-entity weight (see types.mask_pairs)


class PolicyTable:
    """Ordered, registry-backed policy table.

    Every entry shares one call signature, so the same table serves both
    dispatch paths: Python-static (``table[spec.collection]``, one compiled
    program per spec) and branch-free (``jax.lax.switch`` over ``table.fns``
    indexed by a traced ``SliceParams`` policy leaf, one compiled program for
    a whole mixed-policy fleet). Registration order fixes the integer ids, so
    ids are stable across processes as long as registration is module-level.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, int] = {}  # name -> index (insertion order)
        self._fns: list = []

    def register(self, name: str):
        """Decorator: append ``fn`` under ``name`` with the next free id."""
        def deco(fn):
            if name in self._entries:
                raise ValueError(f"{self.kind} policy {name!r} already registered")
            self._entries[name] = len(self._fns)
            self._fns.append(fn)
            return fn
        return deco

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self._entries)

    @property
    def fns(self) -> tuple:
        """Implementations in id order — the ``lax.switch`` branch list."""
        return tuple(self._fns)

    def index(self, name: str) -> int:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(f"unknown {self.kind} policy {name!r}; "
                           f"registered: {list(self._entries)}") from None

    def __getitem__(self, name: str):
        return self._fns[self.index(name)]

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._fns)

    def __iter__(self):
        return iter(self._entries)


COLLECTION_POLICIES = PolicyTable("collection")
TRAINING_POLICIES = PolicyTable("training")

# Sentinel policy name selecting branch-free dispatch (see SWITCHED below).
_SWITCH = "switch"


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """Which variant of the scheduler to run (paper Sec. IV benchmarks).

    ``collection``/``training`` name entries of ``COLLECTION_POLICIES`` /
    ``TRAINING_POLICIES``; the special value ``"switch"`` defers the choice to
    the ``SliceParams`` policy leaves at runtime (branch-free dispatch, see
    ``SWITCHED``/``with_policy``).
    """

    name: str = "ds"
    collection: str = "skew"  # skew | plain | cufull | switch
    training: str = "skew"  # skew | linear | solo | ecfull | switch
    use_lsa: bool = True  # long-term skew amendment (phi/lam multipliers)
    learning_aid: bool = False
    exact: bool = False  # exact Thm.1/Thm.2 matching oracles (host-side)

    @property
    def switched(self) -> bool:
        """True if this spec defers policy choice to the params leaves."""
        return self.collection == _SWITCH or self.training == _SWITCH


DS = AlgoSpec(name="ds")
DS_EXACT = AlgoSpec(name="ds-exact", exact=True)
LDS = AlgoSpec(name="l-ds", learning_aid=True)
NO_SDC = AlgoSpec(name="no-sdc", collection="plain")
NO_SLT = AlgoSpec(name="no-slt", training="linear")
NO_LSA = AlgoSpec(name="no-lsa", use_lsa=False)
GREEDY = AlgoSpec(name="greedy")  # greedy matchers == production path
EC_FULL = AlgoSpec(name="ecfull", training="ecfull")
EC_SELF = AlgoSpec(name="ecself", training="solo")
CU_FULL = AlgoSpec(name="cufull", collection="cufull")

# Branch-free dispatch: policy choice is jax.lax.switch over the tables,
# indexed by the SliceParams policy leaves (with_policy). use_lsa on the spec
# is ignored — the leaves carry it as a {0,1} float32 gate (selects, never a
# Python `if`) — so K slices running DIFFERENT paper variants vmap into ONE
# compiled program (fleet.from_jobs). spec.learning_aid keeps ONE static
# role: it decides whether the L-DS virtual-update path is compiled into the
# program at all (it runs every slot, gated per slice by the learning_aid
# leaf). SWITCHED_NOAID compiles it out — use it when no slice of the fleet
# runs L-DS (from_jobs picks automatically); under it the learning_aid leaf
# is ignored entirely.
SWITCHED = AlgoSpec(name="switched", collection=_SWITCH, training=_SWITCH,
                    learning_aid=True)
SWITCHED_NOAID = AlgoSpec(name="switched-noaid", collection=_SWITCH,
                          training=_SWITCH)

ALL_SPECS = {s.name: s for s in
             [DS, DS_EXACT, LDS, NO_SDC, NO_SLT, NO_LSA, GREEDY, EC_FULL, EC_SELF, CU_FULL]}


def _pin_default_policy_ids() -> None:
    # SliceParams.from_config (types.py) defaults the policy leaves to DS
    # without importing this module; fail fast at import if table order ever
    # drifts (a real raise, not assert: must survive python -O).
    if (COLLECTION_POLICIES.index(DS.collection) != 0
            or TRAINING_POLICIES.index(DS.training) != 0
            or not DS.use_lsa or DS.learning_aid):
        raise RuntimeError(
            "policy table order drifted: SliceParams.from_config hardcodes "
            "the DS policy leaves as collect_id=0/train_id=0/use_lsa=1/"
            "learning_aid=0 (types.py); keep DS's policies registered first "
            "or update those defaults")


def with_policy(params: SliceParams, spec: AlgoSpec) -> SliceParams:
    """Fill the policy leaves of ``params`` from a static ``spec`` so the
    slice can run under branch-free (``SWITCHED``) dispatch."""
    if spec.exact:
        raise ValueError(f"spec {spec.name!r} is exact (host-side oracles); "
                         "it has no branch-free dispatch path")
    if spec.switched:
        raise ValueError("with_policy needs a concrete spec, not SWITCHED")
    return params._replace(
        collect_id=jnp.asarray(COLLECTION_POLICIES.index(spec.collection), jnp.int32),
        train_id=jnp.asarray(TRAINING_POLICIES.index(spec.training), jnp.int32),
        use_lsa=jnp.asarray(1.0 if spec.use_lsa else 0.0, jnp.float32),
        learning_aid=jnp.asarray(1.0 if spec.learning_aid else 0.0, jnp.float32),
    )


# --------------------------------------------------------------------------
# Weights (the per-slot dual prices entering P1'/P2')
# --------------------------------------------------------------------------

def collection_weights(net: NetworkState, mults: Multipliers,
                       cu_mask: Optional[jax.Array] = None,
                       ec_mask: Optional[jax.Array] = None) -> jax.Array:
    """w_ij = d_ij (mu_i - eta_ij - c_ij); the P1' utility rate.

    Ragged padding: entries whose CU or EC is masked are forced to 0 (the
    sampler already zeroes d there, but a caller-supplied net need not), so
    no collection policy can ever select them (they all require w > 0)."""
    w = net.d * (mults.mu[:, None] - mults.eta - net.c)
    if cu_mask is not None or ec_mask is not None:
        cu = cu_mask if cu_mask is not None else jnp.ones_like(w[:, 0])
        ec = ec_mask if ec_mask is not None else jnp.ones_like(w[0, :])
        w = mask_pairs(w, cu, ec, fill=0.0)
    return w


def training_weights(cfg: CocktailConfig | ShapeConfig, net: NetworkState,
                     mults: Multipliers, use_lsa: bool | jax.Array,
                     params: Optional[SliceParams] = None) -> tuple[jax.Array, jax.Array]:
    """Returns (beta (N,M), gamma (N,M,M)).

    beta[i,j]    weight of x[i,j]   (eq. 18 x-coefficient)
    gamma[i,j,k] weight of y[i,j,k] (from queue R[i,j], trained at EC k)
                 = beta[i,k] + eta[i,j] - eta[i,k] - e[j,k]

    ``use_lsa`` is a Python bool on the static dispatch path and a traced
    {0,1} float32 gate under SWITCHED dispatch; the gate multiplies phi/lam,
    which is bit-exact against both static branches (x*1 == x, finite x*0 == 0).

    Ragged padding: any entry touching a masked CU/EC is forced to the large
    negative ``_NEG`` so every training solver (waterfill/coordinate-ascent/
    knapsack) treats it as inactive and allocates exactly zero there.
    """
    _, params = split_config(cfg, params)
    if isinstance(use_lsa, bool):
        phi = mults.phi if use_lsa else jnp.zeros_like(mults.phi)
        lam = mults.lam if use_lsa else jnp.zeros_like(mults.lam)
    else:
        gate = jnp.asarray(use_lsa, jnp.float32)
        phi = mults.phi * gate
        lam = mults.lam * gate
    d_hi, d_lo = params.delta_hi, params.delta_lo
    common = jnp.sum(lam * d_hi[:, None] - phi * d_lo[:, None], axis=0)  # (M,)
    beta = -net.p[None, :] + mults.eta - lam + phi + common[None, :]
    gamma = (beta[:, None, :] + mults.eta[:, :, None]
             - mults.eta[:, None, :] - net.e[None, :, :])
    cu, ec = entity_masks(params)
    beta = mask_pairs(beta, cu, ec)
    gamma = jnp.where(
        (cu[:, None, None] * ec[None, :, None] * ec[None, None, :]) > 0,
        gamma, _NEG)
    return beta, gamma


# --------------------------------------------------------------------------
# Collection policies — shared signature (shape, params, net, mults, queues,
# exact) -> (alpha, theta); registration order fixes the lax.switch branch id.
# --------------------------------------------------------------------------

@COLLECTION_POLICIES.register("skew")
def _collect_skew(shape, params, net, mults, queues, exact):
    cu, ec = entity_masks(params)
    w = collection_weights(net, mults, cu, ec)
    logw = jnp.where(w > 0, jnp.log(jnp.maximum(w, _TINY)), -jnp.inf)
    if exact:
        from . import oracle
        alpha, theta = oracle.exact_collection(np.asarray(logw))
        return jnp.asarray(alpha), jnp.asarray(theta)
    # Kernel-dispatched (Pallas on TPU): the masks are redundant with the
    # masked weights above but pin the padded-pair invariant at the boundary.
    return matching_ops.greedy_collection(logw, cu_mask=cu, ec_mask=ec)


@COLLECTION_POLICIES.register("plain")
def _collect_plain(shape, params, net, mults, queues, exact):
    cu, ec = entity_masks(params)
    w = collection_weights(net, mults)
    # Production path dispatches through the kernels layer: Pallas on TPU,
    # the (identical) jnp greedy elsewhere; both vmap over a slice axis and
    # take the entity masks (masked pairs can never be assigned).
    alpha = matching_ops.greedy_assignment(w, cu_mask=cu, ec_mask=ec)
    return alpha, alpha  # theta = 1 on the selected connection


@COLLECTION_POLICIES.register("cufull")
def _collect_cufull(shape, params, net, mults, queues, exact):
    # Full connection over the *real* entities only: every real EC slot is
    # shared evenly by the n_real connected CUs (theta = 1/n_real each).
    cu, ec = entity_masks(params)
    n_real = jnp.maximum(jnp.sum(cu), 1.0)
    alpha = cu[:, None] * ec[None, :]
    theta = alpha / n_real
    return alpha, theta


# --------------------------------------------------------------------------
# Training policies — shared signature (shape, params, net, mults, queues,
# exact, use_lsa) -> (x, y, z); registered in the same indexed-table scheme.
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pair_index(m: int) -> tuple[np.ndarray, np.ndarray]:
    # Cached per M: this is hit on every trace of every policy variant and
    # np.triu_indices is pure host-side work.
    pj, pk = np.triu_indices(m, k=1)
    return pj.astype(np.int32), pk.astype(np.int32)


def _compose_from_match(match, x_solo, pairs, pa, m):
    """Assemble (x, y, z) from the matching and the pre-solved allocations."""
    pj, pk = pairs
    onehot_j = jax.nn.one_hot(pj, m, dtype=x_solo.dtype)  # (P, M)
    onehot_k = jax.nn.one_hot(pk, m, dtype=x_solo.dtype)
    sel = match[pj, pk]  # (P,) 1 if pair matched
    diag = jnp.diagonal(match)  # (M,)

    x = x_solo * diag[None, :]
    x = x + jnp.einsum("pn,pm->nm", pa.x_j * sel[:, None], onehot_j)
    x = x + jnp.einsum("pn,pm->nm", pa.x_k * sel[:, None], onehot_k)
    y = jnp.einsum("pn,pm,pl->nml", pa.y_jk * sel[:, None], onehot_j, onehot_k)
    y = y + jnp.einsum("pn,pm,pl->nml", pa.y_kj * sel[:, None], onehot_k, onehot_j)
    z = match * (1.0 - jnp.eye(m, dtype=match.dtype))
    return x, y, z


def _train_generic(shape, params, net, mults, queues, exact, use_lsa, solo_fn, pair_fn):
    beta, gamma = training_weights(shape, net, mults, use_lsa, params)
    budgets = net.f / params.rho
    m = shape.n_ec

    x_solo, val_solo = jax.vmap(solo_fn, in_axes=(1, 1, 0), out_axes=(1, 0))(
        beta, queues.r, budgets)

    pj, pk = _pair_index(m)
    pj_a, pk_a = jnp.asarray(pj), jnp.asarray(pk)

    def one_pair(j, k):
        return pair_fn(
            beta[:, j], gamma[:, k, j], beta[:, k], gamma[:, j, k],
            queues.r[:, j], queues.r[:, k], budgets[j], budgets[k],
            net.cap_d[j, k])

    pa = jax.vmap(one_pair)(pj_a, pk_a)
    pair_vals = jnp.zeros((m, m), jnp.float32).at[pj_a, pk_a].set(pa.value)
    pair_vals = pair_vals + pair_vals.T

    # Ragged padding: a masked EC must never be solo-selected nor paired (a
    # (real, padded) pair would otherwise shadow the real EC's solo option —
    # its value approximates the solo objective by a different solver). The
    # greedy path delegates the identical masking to the ops dispatch layer.
    _, ec = entity_masks(params)
    if exact:
        from . import oracle
        val_solo = jnp.where(ec > 0, val_solo, _NEG)
        pair_vals = mask_pairs(pair_vals, ec, ec)
        match = jnp.asarray(oracle.exact_pairing(np.asarray(val_solo), np.asarray(pair_vals)))
    else:
        match = matching_ops.greedy_pairing(val_solo, pair_vals, ec_mask=ec)

    x, y, z = _compose_from_match(match, x_solo, (pj_a, pk_a), pa, m)
    return x, y, z


@TRAINING_POLICIES.register("skew")
def _train_skew(shape, params, net, mults, queues, exact, use_lsa):
    pair_fn = functools.partial(training_alloc.pair_allocate, iters=shape.pair_iters)
    return _train_generic(shape, params, net, mults, queues, exact, use_lsa,
                          training_alloc.solo_waterfill, pair_fn)


@TRAINING_POLICIES.register("linear")
def _train_linear(shape, params, net, mults, queues, exact, use_lsa):
    return _train_generic(shape, params, net, mults, queues, exact, use_lsa,
                          training_alloc.linear_solo, training_alloc.linear_pair)


@TRAINING_POLICIES.register("solo")
def _train_solo(shape, params, net, mults, queues, exact, use_lsa):
    beta, _ = training_weights(shape, net, mults, use_lsa, params)
    budgets = net.f / params.rho
    x, _ = jax.vmap(training_alloc.solo_waterfill, in_axes=(1, 1, 0), out_axes=(1, 0))(
        beta, queues.r, budgets)
    m = shape.n_ec
    return x, jnp.zeros((shape.n_cu, m, m), jnp.float32), jnp.zeros((m, m), jnp.float32)


@TRAINING_POLICIES.register("ecfull")
def _train_ecfull(shape, params, net, mults, queues, exact, use_lsa):
    beta, gamma = training_weights(shape, net, mults, use_lsa, params)
    budgets = net.f / params.rho
    x, y, _ = training_alloc.full_allocate(beta, gamma, queues.r, budgets, net.cap_d)
    m = shape.n_ec
    _, ec = entity_masks(params)
    z = (jnp.ones((m, m), jnp.float32) - jnp.eye(m, dtype=jnp.float32))
    return x, y, z * (ec[:, None] * ec[None, :])


# --------------------------------------------------------------------------
# Dynamics (queues + multiplier SGD)
# --------------------------------------------------------------------------

def _served(dec_alpha, dec_theta, net, queues):
    """Samples actually moved CU->EC: alpha*theta*d, capped by Q backlog."""
    req = dec_alpha * dec_theta * net.d
    tot = jnp.sum(req, axis=1)
    scale = jnp.minimum(1.0, queues.q / jnp.maximum(tot, _TINY))
    return req * scale[:, None]


def update_multipliers(cfg: CocktailConfig | ShapeConfig, mults: Multipliers,
                       net: NetworkState, served: jax.Array, x: jax.Array,
                       y: jax.Array, use_lsa: bool | jax.Array,
                       step: jax.Array | float,
                       params: Optional[SliceParams] = None) -> Multipliers:
    _, params = split_config(cfg, params)
    dep_r = x + jnp.sum(y, axis=2)  # leaves queue R[i,j]
    trained_at = x + jnp.sum(y, axis=1)  # trained at EC k
    tot_j = jnp.sum(trained_at, axis=0)
    d_hi, d_lo = params.delta_hi, params.delta_lo

    # Ragged padding: masked entities see zero flows, so their gradients are
    # already zero; the explicit mask products pin the invariant (padded
    # multipliers stay exactly 0) independent of upstream guarantees.
    cu, ec = entity_masks(params)
    link = cu[:, None] * ec[None, :]
    mu = jnp.maximum(mults.mu + step * (net.arrivals - jnp.sum(served, axis=1)), 0.0) * cu
    eta = jnp.maximum(mults.eta + step * (served - dep_r), 0.0) * link
    if isinstance(use_lsa, bool) and not use_lsa:
        phi, lam = mults.phi, mults.lam
    else:
        phi = jnp.maximum(mults.phi + step * (d_lo[:, None] * tot_j[None, :] - trained_at), 0.0) * link
        lam = jnp.maximum(mults.lam + step * (trained_at - d_hi[:, None] * tot_j[None, :]), 0.0) * link
        if not isinstance(use_lsa, bool):
            # Traced {0,1} gate (SWITCHED dispatch): select, never a Python if.
            gate = jnp.asarray(use_lsa, jnp.float32) > 0
            phi = jnp.where(gate, phi, mults.phi)
            lam = jnp.where(gate, lam, mults.lam)
    return Multipliers(mu=mu, eta=eta, phi=phi, lam=lam)


def apply_decision(cfg: CocktailConfig | ShapeConfig, queues: QueueState,
                   net: NetworkState, served: jax.Array, x: jax.Array,
                   y: jax.Array) -> QueueState:
    dep_r = x + jnp.sum(y, axis=2)
    trained_at = x + jnp.sum(y, axis=1)
    q = jnp.maximum(queues.q - jnp.sum(served, axis=1), 0.0) + net.arrivals
    r = jnp.maximum(queues.r - dep_r, 0.0) + served
    return QueueState(q=q, r=r, omega=queues.omega + trained_at)


# --------------------------------------------------------------------------
# One slot
# --------------------------------------------------------------------------

class SlotRecord(NamedTuple):
    cost: jax.Array
    trained: jax.Array
    q_backlog: jax.Array
    r_backlog: jax.Array
    skew: jax.Array


def stack_slot_records(recs: Sequence[SlotRecord]) -> SlotRecord:
    """Stack per-slot records time-major, mirroring what ``lax.scan`` produces
    on the jitted path (leading axis = slot index)."""
    return SlotRecord(*[jnp.stack([getattr(r, f) for r in recs])
                        for f in SlotRecord._fields])


def skew_degree(cfg: CocktailConfig | ShapeConfig | SliceParams, omega: jax.Array,
                params: Optional[SliceParams] = None) -> jax.Array:
    """max_{i,j} | Omega_ij / sum_l Omega_lj - zeta_i / sum zeta | (eq. 9 LHS)."""
    if params is None and isinstance(cfg, SliceParams):
        params = cfg
    else:
        _, params = split_config(cfg, params)
    props = params.proportions
    tot = jnp.sum(omega, axis=0, keepdims=True)
    frac = omega / jnp.maximum(tot, _TINY)
    dev = jnp.abs(frac - props[:, None])
    return jnp.max(jnp.where(tot > _TINY, dev, 0.0))


def _pi(params: SliceParams) -> jax.Array:
    """L-DS distance parameter pi = sqrt(eps) * log^2(eps) ([24],[25])."""
    return jnp.sqrt(params.eps) * jnp.log(params.eps) ** 2


def _tree_affine(a: Multipliers, b: Multipliers, shift: jax.Array) -> Multipliers:
    return jax.tree.map(lambda x, y: x + y - shift, a, b)


def _require_policy_leaves(params: SliceParams) -> None:
    missing = [f for f in ("collect_id", "train_id", "use_lsa", "learning_aid")
               if getattr(params, f) is None]
    if missing:
        raise TypeError(
            f"SWITCHED dispatch needs the SliceParams policy leaves, but "
            f"{missing} are unset; fill them with datasche.with_policy(params, "
            f"spec) or build the fleet via FleetEngine.from_jobs")


def step(cfg: CocktailConfig | ShapeConfig, spec: AlgoSpec, state: SchedulerState,
         net: Optional[NetworkState] = None,
         params: Optional[SliceParams] = None) -> tuple[SchedulerState, SlotRecord, Decision]:
    """Run one slot. Jittable when spec.exact is False (cfg/spec static,
    params traced); vmappable over a leading slice axis of (params, state).

    Two dispatch modes:
      * Python-static (any named spec): policy functions are resolved from
        the tables at trace time — one compiled program per (shape, spec).
      * Branch-free (``spec.switched``, i.e. ``SWITCHED``/``SWITCHED_NOAID``):
        the policy choice is ``jax.lax.switch`` over the tables indexed by
        the traced ``SliceParams`` policy leaves, and the learning-aid
        virtual update is gated by a select instead of a Python ``if`` — so
        K slices running different algorithms vmap into ONE compiled
        program. Under ``SWITCHED`` the virtual plain-P1/P2 path runs every
        slot (its result is masked out for slices with learning_aid=0) — the
        price of branch-freedom; ``SWITCHED_NOAID`` compiles it out for
        fleets with no L-DS slice and ignores the learning_aid leaf.
    """
    shape, params = split_config(cfg, params)
    rng, k_net = jax.random.split(state.rng)
    if net is None:
        # Per-slot noise from k_net; persistent heterogeneity from the
        # slot-invariant het_key the state carries unchanged.
        net = sample_network_state(k_net, shape, state.t, params,
                                   het_key=state.het_key)

    switched = spec.switched
    if switched:
        _require_policy_leaves(params)
        use_lsa: bool | jax.Array = jnp.asarray(params.use_lsa, jnp.float32)
        aid = jnp.asarray(params.learning_aid, jnp.float32) > 0
        if spec.learning_aid:
            # Same affine as _tree_affine (x + y - shift), selected per slice
            # so the aid=1 branch stays bit-exact against the static L-DS path.
            pi = _pi(params)
            eff = jax.tree.map(lambda m, e: jnp.where(aid, m + e - pi, m),
                               state.mults, state.emp_mults)
        else:
            eff = state.mults  # SWITCHED_NOAID: aid leaf ignored wholesale
    else:
        use_lsa = spec.use_lsa
        if spec.learning_aid:
            eff = _tree_affine(state.mults, state.emp_mults, _pi(params))
        else:
            eff = state.mults

    if switched:
        alpha, theta = jax.lax.switch(
            params.collect_id,
            [(lambda p, n, m, q, fn=fn: fn(shape, p, n, m, q, False))
             for fn in COLLECTION_POLICIES.fns],
            params, net, eff, state.queues)
        x, y, z = jax.lax.switch(
            params.train_id,
            [(lambda p, n, m, q, fn=fn: fn(shape, p, n, m, q, False, use_lsa))
             for fn in TRAINING_POLICIES.fns],
            params, net, eff, state.queues)
    else:
        collect = COLLECTION_POLICIES[spec.collection]
        train = TRAINING_POLICIES[spec.training]
        alpha, theta = collect(shape, params, net, eff, state.queues, spec.exact)
        x, y, z = train(shape, params, net, eff, state.queues, spec.exact, use_lsa)

    served = _served(alpha, theta, net, state.queues)
    cost = framework_cost(net, served, x, y)
    queues = apply_decision(shape, state.queues, net, served, x, y)
    mults = update_multipliers(shape, state.mults, net, served, x, y,
                               use_lsa, params.eps, params)

    emp = state.emp_mults
    if spec.learning_aid:
        # Virtual decisions from plain P1/P2 with the empirical multipliers;
        # they update Theta' only (diminishing step), never the real queues.
        v_alpha, v_theta = _collect_plain(shape, params, net, state.emp_mults,
                                          state.queues, False)
        v_x, v_y, _ = _train_linear(shape, params, net, state.emp_mults,
                                    state.queues, False, use_lsa)
        v_served = _served(v_alpha, v_theta, net, state.queues)
        sigma = params.sigma0 / jnp.sqrt(state.t.astype(jnp.float32) + 1.0)
        emp = update_multipliers(shape, state.emp_mults, net, v_served, v_x, v_y,
                                 use_lsa, sigma, params)
        if switched:
            # learning_aid gate: slices without the aid keep Theta' frozen.
            emp = jax.tree.map(lambda new, old: jnp.where(aid, new, old),
                               emp, state.emp_mults)

    trained = jnp.sum(x) + jnp.sum(y)
    new_state = SchedulerState(
        queues=queues, mults=mults, emp_mults=emp,
        t=state.t + 1,
        total_cost=state.total_cost + cost,
        total_trained=state.total_trained + trained,
        uploaded=state.uploaded + jnp.sum(served, axis=1),
        rng=rng,
        het_key=state.het_key,
    )
    rec = SlotRecord(
        cost=cost, trained=trained,
        q_backlog=jnp.sum(queues.q), r_backlog=jnp.sum(queues.r),
        skew=skew_degree(shape, queues.omega, params),
    )
    dec = Decision(alpha=alpha, theta=theta, x=x, y=y, z=z)
    return new_state, rec, dec


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _run_scan(shape: ShapeConfig, spec: AlgoSpec, n_slots: int,
              params: SliceParams, state: SchedulerState) -> tuple[SchedulerState, SlotRecord]:
    def body(s, _):
        s2, rec, _ = step(shape, spec, s, params=params)
        return s2, rec

    return jax.lax.scan(body, state, None, length=n_slots)


def run(cfg: CocktailConfig | ShapeConfig, spec: AlgoSpec, n_slots: int,
        state: Optional[SchedulerState] = None,
        params: Optional[SliceParams] = None) -> tuple[SchedulerState, SlotRecord]:
    """Run n_slots of the online algorithm; returns (final state, stacked
    per-slot records). Only ShapeConfig/AlgoSpec trigger recompilation —
    slices that differ only in SliceParams share one compiled program."""
    shape, params = split_config(cfg, params)
    if state is None:
        state = init_state(shape, params, seed=getattr(cfg, "seed", 0))
    if not spec.exact:
        return _run_scan(shape, spec, n_slots, params, state)
    recs = []
    for _ in range(n_slots):
        state, rec, _ = step(shape, spec, state, params=params)
        recs.append(rec)
    return state, stack_slot_records(recs)


_pin_default_policy_ids()
