"""SliceJob: the unified per-slice descriptor of the fleet frontend.

A fleet slice is fully described by (what network, which algorithm, which
randomness): a ``CocktailConfig``, an ``AlgoSpec`` and a seed. ``SliceJob``
bundles the three so :meth:`FleetEngine.from_jobs` can transparently build
any fleet the scheduler supports:

  * homogeneous      — every job shares one shape and one spec,
  * ragged           — mixed true (N, M), padded + masked (PR 2),
  * mixed-policy     — different ``AlgoSpec`` per slice, dispatched
                       branch-free via the indexed policy tables (SWITCHED),
  * any composition of the above — ragged x mixed-policy works.

The older ``from_configs`` / ``from_ragged_configs`` constructors are thin
shims over ``from_jobs``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from .datasche import DS, AlgoSpec, with_policy
from .types import CocktailConfig, ShapeConfig, SliceParams


@dataclasses.dataclass(frozen=True)
class SliceJob:
    """One fleet slice: network config + scheduling algorithm + seed.

    ``seed`` defaults to ``config.seed``; ``name`` is display-only metadata
    (per-slice reporting in examples/benchmarks), never part of the program.
    """

    config: CocktailConfig
    spec: AlgoSpec = DS
    seed: Optional[int] = None
    name: Optional[str] = None

    def __post_init__(self):
        if self.spec.switched:
            raise ValueError("a SliceJob carries a concrete AlgoSpec; "
                             "SWITCHED is an engine-internal dispatch mode")
        if self.spec.exact:
            raise ValueError(
                f"spec {self.spec.name!r} is exact (host-side oracles) and "
                "cannot join a fleet; use datasche.run per slice instead")

    @property
    def resolved_seed(self) -> int:
        return int(self.config.seed if self.seed is None else self.seed)

    @property
    def shape(self) -> ShapeConfig:
        return self.config.shape

    def params(self, pad_shape: Optional[ShapeConfig] = None,
               policy_leaves: bool = False) -> SliceParams:
        """This job's ``SliceParams``, optionally padded to ``pad_shape`` and
        with the policy leaves filled from the spec (branch-free dispatch)."""
        p = SliceParams.from_config(self.config, pad_shape=pad_shape)
        return with_policy(p, self.spec) if policy_leaves else p


JobLike = Union[SliceJob, CocktailConfig]


def as_jobs(jobs: Sequence[JobLike], spec: AlgoSpec = DS) -> list[SliceJob]:
    """Normalise a mixed list of ``SliceJob`` / bare ``CocktailConfig`` (the
    latter get ``spec``) into a list of jobs."""
    out = []
    for j in jobs:
        if isinstance(j, SliceJob):
            out.append(j)
        elif isinstance(j, CocktailConfig):
            out.append(SliceJob(config=j, spec=spec))
        else:
            raise TypeError(f"expected SliceJob or CocktailConfig, got {type(j).__name__}")
    return out
