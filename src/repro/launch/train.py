"""End-to-end Cocktail training driver.

Wires every layer together on whatever devices exist:

  Cocktail scheduler (core)  ->  per-slot x/y/z decisions
  CocktailSampler (data)     ->  per-EC batch composition + sample weights
  pjit train step (launch)   ->  weighted-psum aggregation == paper eq. 15
  CheckpointManager          ->  atomic snapshots + auto-resume (kill -9 safe)

ECs are the data-parallel shard groups; their simulated capacities f_j(t)
are heterogeneous, so the scheduler naturally throttles slow workers
(straggler mitigation) while the (phi, lam) multipliers repair the induced
data skew — the paper's mechanism doing cluster-scheduler duty.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --reduced \
        --steps 200 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import core
from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced as make_reduced
from repro.data import CocktailSampler, TokenSource
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamWConfig, AdamWState, adamw_init
from repro.parallel.sharding import (batch_axes, mesh_context,
                                     shard_params_pspecs)


def build_cocktail(n_cu: int, n_ec: int, seed: int) -> core.CocktailConfig:
    # heterogeneous EC capacities (paper Sec. IV-C): stragglers are the
    # low-capacity workers
    caps = tuple(float(c) for c in
                 np.random.default_rng(seed).choice([8000, 14000, 20000, 48000], n_ec))
    return core.CocktailConfig(n_cu=n_cu, n_ec=n_ec, eps=0.1, delta=0.05,
                               f_base=caps, pair_iters=30, seed=seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)  # global
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-cu", type=int, default=12)
    ap.add_argument("--slot-every", type=int, default=10)  # steps per slot
    ap.add_argument("--sched-warmup", type=int, default=8)  # max warmup slots
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--scheduler", default="ds", choices=sorted(core.ALL_SPECS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    model = build_model(cfg)
    mesh = make_host_mesh()
    dp = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                      for a in batch_axes(mesh)]))
    n_ec = max(dp, 2)
    assert args.batch % n_ec == 0, "global batch must divide into ECs"

    # --- paper core: scheduler + non-IID sources + sampler ---
    ck = build_cocktail(args.n_cu, n_ec, args.seed)
    spec = core.ALL_SPECS[args.scheduler]
    sched_state = core.init_state(ck)
    # warm-up slots: EC-side queues R start empty, so the first few slots
    # only collect; spin the scheduler until data is actually being trained
    warm_dec = None
    for _ in range(args.sched_warmup):
        sched_state, _, warm_dec = core.step(ck, spec, sched_state)
        if float(warm_dec.x.sum() + warm_dec.y.sum()) > 0:
            break
    sources = [TokenSource(i, cfg.vocab_size, args.seq, seed=args.seed)
               for i in range(args.n_cu)]
    sampler = CocktailSampler(ck, sources, batch_per_ec=args.batch // n_ec,
                              seed=args.seed)

    # --- model + optimizer state ---
    opt_cfg = AdamWConfig(lr=args.lr)
    with mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        opt_state = adamw_init(params)
        p_specs = shard_params_pspecs(params, mesh)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        p_sh = ns(p_specs)
        o_sh = AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = jax.tree.map(jax.device_put, opt_state, o_sh,
                                 is_leaf=lambda x: isinstance(x, jax.Array))

        step_fn = jax.jit(make_train_step(model, opt_cfg, total_steps=args.steps),
                          donate_argnums=(0, 1))

        start = 0
        ckpt = None
        if args.checkpoint_dir:
            ckpt = CheckpointManager(args.checkpoint_dir,
                                     every_steps=args.checkpoint_every)
            resumed = ckpt.resume({"params": params, "opt": opt_state},
                                  shardings={"params": p_sh, "opt": o_sh})
            if resumed is not None:
                tree, meta, start = resumed
                params, opt_state = tree["params"], tree["opt"]
                print(f"resumed from step {start}")

        decision = warm_dec
        losses = []
        t0 = time.time()
        for it in range(start, args.steps):
            if decision is None or it % args.slot_every == 0:
                sched_state, rec, new_dec = core.step(ck, spec, sched_state)
                # steps run at a much finer timescale than slots: between
                # scheduler updates workers keep training the last scheduled
                # mix, so an occasional empty slot (multiplier oscillation)
                # does not stall the optimizer
                if decision is None or float(new_dec.x.sum() + new_dec.y.sum()) > 0:
                    decision = new_dec
            host_batch = sampler.sample(decision)
            batch = {
                "tokens": jnp.asarray(host_batch["tokens"]),
                "labels": jnp.asarray(host_batch["labels"]),
                "weights": jnp.asarray(host_batch["weights"]),
            }
            if cfg.family == "encdec":  # stubbed modality frontends
                batch["frames"] = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(args.seed), it),
                    (args.batch, cfg.enc_ctx, cfg.d_model))
            if cfg.family == "vlm":
                batch["patches"] = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(args.seed), it),
                    (args.batch, cfg.n_img_tokens, cfg.d_model))
            bax = batch_axes(mesh)
            def put(x):
                spec = P(bax, *([None] * (x.ndim - 1)))
                return jax.device_put(x, NamedSharding(mesh, spec))
            batch = {k: put(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if ckpt is not None:
                ckpt.maybe_save(it + 1, {"params": params, "opt": opt_state},
                                extra={"arch": cfg.name, "step": it + 1})
            if (it + 1) % args.log_every == 0:
                sk = float(core.skew_degree(ck, sched_state.queues.omega))
                print(f"step {it+1:5d} loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f} "
                      f"sched_cost={float(sched_state.total_cost):.0f} "
                      f"skew={sk:.4f} "
                      f"({(time.time()-t0)/(it+1-start):.2f}s/step)")

        nonzero = [l for l in losses if l > 0]
        summary = {
            "arch": cfg.name, "steps": args.steps,
            "first_loss": float(np.mean(nonzero[:3])) if nonzero else None,
            "last_loss": float(np.mean(nonzero[-10:])) if nonzero else None,
            "min_loss": float(np.min(nonzero[3:])) if len(nonzero) > 3 else None,
            "scheduler": args.scheduler,
            "sched_cost": float(sched_state.total_cost),
            "sched_trained": float(sched_state.total_trained),
            "skew_degree": float(core.skew_degree(ck, sched_state.queues.omega)),
        }
        print(json.dumps(summary))
        return summary


if __name__ == "__main__":
    main()
