"""Production mesh construction.

Single pod: 16 x 16 = 256 chips (data x model).
Multi-pod:  2 x 16 x 16 = 512 chips (pod x data x model); the `pod` axis is
the slow (DCN/ICI-inter-pod) dimension — params replicate across it and the
gradient all-reduce over it is where compression applies.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def shard_leading_axis(tree, mesh, axis: str = "data"):
    """Shard every leaf of a pytree along its leading axis over one mesh axis.

    Used by the fleet engine to spread the K-slice batch axis of stacked
    ``SliceParams`` / ``SchedulerState`` pytrees across devices
    (``NamedSharding(mesh, P(axis, None, ...))``); all trailing axes stay
    replicated. K must be divisible by the mesh axis size.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    def put(leaf):
        spec = PartitionSpec(axis, *([None] * (leaf.ndim - 1)))
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree)
