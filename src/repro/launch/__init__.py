"""Launch layer: production mesh, train/serve steps, multi-pod dry-run."""
from .mesh import make_host_mesh, make_production_mesh
from .steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["make_host_mesh", "make_production_mesh", "make_prefill_step",
           "make_serve_step", "make_train_step"]
