"""Step builders: the pjit-able train / serve step functions.

The Cocktail integration point is the `weights` field of the batch: the
scheduler's per-EC sample counts become per-sample weights, so the global
weighted-mean loss (and hence the single gradient all-reduce) implements the
parameter server's |D_j|-weighted aggregation (paper eq. 15) exactly.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import ModelApi
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update, cosine_schedule


def make_train_step(model: ModelApi, opt_cfg: AdamWConfig,
                    total_steps: int = 10_000, warmup_steps: int = -1,
                    bf16_comms: bool = True):
    """bf16_comms (§Perf iteration 4): differentiate w.r.t. the bf16-cast
    params (so the gradient reduce-scatter runs in bf16, upcast to f32
    locally afterwards) and pin the cast before the FSDP weight all-gathers
    with an optimization barrier (XLA otherwise reorders gather-then-convert
    and moves f32 bytes over the wire). Master weights/optimizer stay f32."""
    if warmup_steps < 0:
        warmup_steps = max(min(100, total_steps // 10), 1)

    from repro.models.layers import cast_tree

    def train_step(params, opt_state: AdamWState, batch):
        if bf16_comms:
            cdt = jnp.dtype(model.cfg.compute_dtype)
            params_c = jax.lax.optimization_barrier(cast_tree(params, cdt))
            (loss, aux), grads_c = jax.value_and_grad(
                model.loss, has_aux=True)(params_c, batch)
            # pin the cross-DP gradient reduction to the bf16 values: the
            # sharding constraint forces the reduce(-scatter) to the storage
            # layout BEFORE the local f32 upcast (otherwise XLA widens first
            # and reduces f32 on the wire)
            from jax.sharding import NamedSharding
            from repro.parallel.sharding import current_mesh, shard_params_pspecs
            mesh = current_mesh()
            if mesh is not None:
                specs = shard_params_pspecs(grads_c, mesh)
                grads_c = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, s)), grads_c, specs)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads_c, params)
        else:
            (loss, aux), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
        lr_scale = cosine_schedule(opt_state.step, total_steps, warmup_steps)
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg, lr_scale)
        metrics = {"loss": loss, "tokens": aux["tokens"], **om}
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(model: ModelApi, greedy: bool = True):
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step


def make_prefill_step(model: ModelApi):
    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step
