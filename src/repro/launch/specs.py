"""Abstract input specs + shardings for every (arch x shape x mesh) cell.

``input_specs(cfg, shape_name)`` returns ShapeDtypeStruct stand-ins (no
device allocation) for the lowered step's inputs; ``*_pspecs`` build the
matching PartitionSpecs. Cache sharding policy (decode):

  batch dim   -> DP axes when divisible,
  kv heads    -> 'model' when divisible,
  else seq    -> 'model' (and the DP axes too when batch can't shard, e.g.
                 long_500k with global_batch=1) — decode attention over a
                 sequence-sharded KV is handled by GSPMD with a partial
                 softmax + all-reduce (sequence-parallel decode).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig
from repro.models import ModelApi
from repro.parallel.sharding import batch_axes


def _dp_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in batch_axes(mesh)]))


def _axis_size(mesh: Mesh, name: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def batch_abstract(cfg: ArchConfig, shape_name: str, kind: str) -> dict:
    """ShapeDtypeStructs for a train/prefill batch."""
    seq, gb, _ = SHAPES[shape_name]
    out: dict[str, Any] = {}
    if cfg.family == "vlm":
        text = seq - cfg.n_img_tokens
        out["tokens"] = jax.ShapeDtypeStruct((gb, text), jnp.int32)
        out["patches"] = jax.ShapeDtypeStruct((gb, cfg.n_img_tokens, cfg.d_model), jnp.float32)
        if kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((gb, text), jnp.int32)
    elif cfg.family == "encdec":
        out["tokens"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
        out["frames"] = jax.ShapeDtypeStruct((gb, cfg.enc_ctx, cfg.d_model), jnp.float32)
        if kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
        if kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
    if kind == "train":
        out["weights"] = jax.ShapeDtypeStruct((gb,), jnp.float32)
    return out


def batch_pspecs(cfg: ArchConfig, shape_name: str, kind: str, mesh: Mesh) -> dict:
    seq, gb, _ = SHAPES[shape_name]
    bax = batch_axes(mesh)
    b = bax if gb % _dp_size(mesh) == 0 else None
    specs = {}
    for name in batch_abstract(cfg, shape_name, kind):
        if name == "weights":
            specs[name] = P(b)
        elif name in ("patches", "frames"):
            specs[name] = P(b, None, None)
        else:
            specs[name] = P(b, None)
    return specs


def cache_pspecs(cfg: ArchConfig, cache_abs: dict, mesh: Mesh, gb: int) -> dict:
    """PartitionSpecs for a decode cache pytree (see module docstring)."""
    bax = batch_axes(mesh)
    dp = _dp_size(mesh)
    msz = _axis_size(mesh, "model")
    b = bax if (gb % dp == 0 and gb >= dp) else None

    def leaf_spec(name: str, shape: tuple) -> P:
        if len(shape) == 0:
            return P()
        if name.startswith(("k", "v", "attn_k", "attn_v", "cross_k", "cross_v")) and len(shape) == 5:
            n_, bb, s, h, hd = shape
            h_ax = "model" if h % msz == 0 and h >= msz else None
            s_parts = []
            if b is None and s % dp == 0:
                s_parts.extend(bax)
            if h_ax is None and s % msz == 0:
                s_parts.append("model")
            s_ax = tuple(s_parts) if s_parts else None
            return P(None, b, s_ax, h_ax, None)
        if name.startswith(("kv_pos", "attn_pos")) and len(shape) == 3:
            n_, bb, s = shape
            s_parts = []
            if b is None and s % dp == 0:
                s_parts.extend(bax)
            kvname = name.replace("kv_pos", "k").replace("attn_pos", "attn_k")
            kv_shape = next((sh for nm, sh in abs_shapes if nm == kvname), None)
            if kv_shape is not None:
                h = kv_shape[3]
                if not (h % msz == 0 and h >= msz) and s % msz == 0:
                    s_parts.append("model")
            s_ax = tuple(s_parts) if s_parts else None
            return P(None, b, s_ax)
        if name == "conv" and len(shape) == 4:  # (L, B, K-1, DI)
            di = shape[3]
            return P(None, b, None, "model" if di % msz == 0 else None)
        if name == "h" and len(shape) == 4:  # mamba1 (L, B, DI, N)
            di = shape[2]
            return P(None, b, "model" if di % msz == 0 else None, None)
        if name == "h" and len(shape) == 5:  # mamba2 (L, B, H, N, P)
            h = shape[2]
            return P(None, b, "model" if h % msz == 0 else None, None, None)
        return P(*([None] * len(shape)))

    abs_shapes = [(nm, tuple(leaf.shape)) for nm, leaf in cache_abs.items()]
    return {nm: leaf_spec(nm, tuple(leaf.shape)) for nm, leaf in cache_abs.items()}


def decode_abstract(cfg: ArchConfig, model: ModelApi, shape_name: str):
    """(cache, tokens) ShapeDtypeStructs for a decode cell: a cache holding
    `seq` tokens of context plus the next-token input."""
    seq, gb, _ = SHAPES[shape_name]
    cache_abs = jax.eval_shape(functools.partial(model.init_cache, gb, seq))
    tokens = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
    return cache_abs, tokens


def decode_pspecs(cfg: ArchConfig, cache_abs: dict, shape_name: str, mesh: Mesh):
    seq, gb, _ = SHAPES[shape_name]
    bax = batch_axes(mesh)
    b = bax if (gb % _dp_size(mesh) == 0 and gb >= _dp_size(mesh)) else None
    return cache_pspecs(cfg, cache_abs, mesh, gb), P(b, None)
