"""Batched decode serving driver: prefill-free KV-cache generation demo.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_serve_step
from repro.models import build_model
from repro.parallel.sharding import mesh_context


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    model = build_model(cfg)
    mesh = make_host_mesh()

    with mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(args.seed))
        max_len = args.prompt_len + args.gen
        cache = model.init_cache(args.batch, max_len)
        if cfg.family == "encdec":
            from repro.models import encdec
            frames = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, cfg.enc_ctx, cfg.d_model))
            cache = encdec.prefill_cross(cfg, params, frames, cache)
        step = jax.jit(make_serve_step(model), donate_argnums=(1,))

        rng = np.random.default_rng(args.seed)
        prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
        # teacher-forced prefill via repeated decode (prefill kernel covered
        # by the prefill_32k dry-run cells)
        tok = None
        t0 = time.time()
        for t in range(args.prompt_len):
            tok, cache = step(params, cache,
                              jnp.asarray(prompt[:, t:t + 1], jnp.int32))
        generated = []
        for _ in range(args.gen):
            tok, cache = step(params, cache, tok)
            generated.append(np.asarray(tok)[:, 0])
        dt = time.time() - t0
        out = np.stack(generated, axis=1)
        summary = {
            "arch": cfg.name, "batch": args.batch, "generated": args.gen,
            "tokens_per_s": round(args.batch * (args.prompt_len + args.gen) / dt, 1),
            "sample_tokens": out[0][:8].tolist(),
        }
        print(json.dumps(summary))
        return summary


if __name__ == "__main__":
    main()
