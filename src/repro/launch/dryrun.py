import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on 512
placeholder host devices and extract the roofline terms.

MUST be invoked as its own process (the XLA flag above must precede any jax
initialization — hence the import-position violation, which is deliberate
and required):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
        --shape train_4k --mesh pod --out experiments/dryrun

Outputs one JSON per cell with:
  memory: per-device argument/temp/peak bytes (compiled.memory_analysis())
  cost:   per-device HLO flops + bytes accessed (compiled.cost_analysis())
  collectives: per-op-kind byte totals parsed from the post-SPMD HLO
  roofline: compute/memory/collective seconds vs TPU v5e constants
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, all_configs, get_config  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import AdamWConfig, AdamWState, adamw_init  # noqa: E402
from repro.parallel.sharding import mesh_context, shard_params_pspecs  # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link
HBM_BYTES = 16 * 2 ** 30  # v5e HBM capacity


def analytic_memory(cfg, shape_name: str, kind: str, mesh_shape: tuple,
                    cache_abs=None, cache_specs=None, style: str = "tp") -> dict:
    """TPU-projected per-device memory residency + HBM traffic (bytes).

    The compiled CPU artifact over-materialises (different fusion heuristics,
    f32 promotion of reductions), so the ``memory_s`` roofline term uses this
    analytic model; the HLO-derived traffic is reported alongside as an
    upper bound. Constants documented in EXPERIMENTS.md §Roofline.
    """
    seq, gb, _ = SHAPES[shape_name]
    n_chips = 1
    for d in mesh_shape:
        n_chips *= d
    model_sz = mesh_shape[-1]
    dp = n_chips // model_sz
    p_total = cfg.n_params()
    p_active = cfg.n_active_params()
    tok_dev = gb * seq // dp
    b_dev = max(gb // dp, 1)
    d_model, n_layers = cfg.d_model, cfg.n_layers
    v_shard = (cfg.vocab_size // model_sz if cfg.vocab_size % model_sz == 0
               else cfg.vocab_size)

    if kind == "train":
        # fp32 master + adam m/v sharded over (data x model); bf16 cast and
        # f32 grads are transient but coexist with activations at peak.
        state = p_total * 12 / n_chips
        transients = p_total * 6 / n_chips  # bf16 copy + f32 grad shard
        act = n_layers * b_dev * seq * d_model * 2  # remat: one carry/layer
        if style == "tp_sp":  # sequence-sharded carries
            act /= model_sz
        logits = 2 * tok_dev * v_shard * 4
        residency = state + transients + act + logits
        # traffic: 3 weight passes (fwd + remat + bwd) over the gathered TP
        # shard; optimizer read/write; activation carries w+r; logits io.
        w_shard = p_active * 2 / model_sz
        traffic = 3 * w_shard + p_total * 24 / n_chips + 2 * act + 2 * logits
    elif kind == "prefill":
        state = p_total * 2 / n_chips  # bf16 serving weights
        act = b_dev * seq * d_model * 2 * 4  # few live layers, no bwd
        kv = 0.0
        if cfg.n_kv_heads and cfg.family not in ("ssm",):
            kv = (n_layers * b_dev * seq * cfg.n_kv_heads
                  * cfg.resolved_head_dim * 2 * 2 / model_sz)
        residency = state + act + kv
        traffic = p_active * 2 / model_sz + 2 * act + kv
    else:  # decode
        state = p_total * 2 / n_chips
        cache_dev = 0.0
        if cache_abs is not None:
            ms = dict(zip(("pod", "data", "model")[-len(mesh_shape):], mesh_shape))
            for name, leaf in cache_abs.items():
                nb = float(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
                shards = 1
                if cache_specs is not None and name in cache_specs:
                    spec = getattr(cache_specs[name], "spec", cache_specs[name])
                    for entry in spec:
                        axes = (entry,) if isinstance(entry, str) else (entry or ())
                        for ax in axes:
                            shards *= ms.get(ax, 1)
                cache_dev += nb / shards
        residency = state + cache_dev
        # per decoded token: all weights (TP shard) + the whole local cache
        traffic = p_active * 2 / model_sz + cache_dev
    return {"residency_bytes": float(residency), "traffic_bytes": float(traffic),
            "fits_hbm": bool(residency <= HBM_BYTES)}


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*((?:\(|[a-z0-9]+\[)[^)]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes per collective kind (per-device, post-SPMD HLO).

    For while-loop bodies (scan-over-layers) HLO lists the body once; we
    multiply by the trip count parsed from the loop metadata when present.
    """
    out: dict[str, float] = {}
    trip = 1
    trip_counts: dict[str, int] = {}
    # map computation name -> trip count from while loops
    for m in re.finditer(r"while\([^)]*\).*?body=%?([\w.\-]+)", hlo_text):
        pass
    # conservative: detect known trip counts via "trip_count=N" backend hints
    for line in hlo_text.splitlines():
        mm = _COLL_RE.search(line)
        if not mm:
            continue
        kind = mm.group(2).lower()
        nbytes = _type_bytes(mm.group(1))
        out[kind] = out.get(kind, 0.0) + float(nbytes)
    return out


def while_trip_counts(hlo_text: str) -> list[int]:
    """Extract known trip counts (xla marks them in loop backend configs)."""
    return [int(x) for x in re.findall(r'"known_trip_count":\{"n":"(\d+)"\}', hlo_text)]


def _scan_collective_multiplier(hlo_text: str) -> dict:
    """Collectives inside while bodies execute trip_count times. We detect
    which computations are while bodies with known trip counts and scale
    collective bytes found inside them."""
    # split HLO into computations
    comps = re.split(r"\n(?=%?[\w.\-]+ \([\w.,%: \[\]\-]*\) -> )", hlo_text)
    # find while calls: body=%name with known_trip_count in same line/block
    body_trips: dict[str, int] = {}
    for m in re.finditer(r'body=%?([\w.\-]+)[^\n]*', hlo_text):
        line = m.group(0)
        t = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
        if t:
            body_trips[m.group(1)] = int(t.group(1))
    totals: dict[str, float] = {}
    for comp in comps:
        header = comp.splitlines()[0] if comp.splitlines() else ""
        name_m = re.match(r"%?([\w.\-]+) \(", header)
        mult = 1
        if name_m and name_m.group(1) in body_trips:
            mult = body_trips[name_m.group(1)]
        for line in comp.splitlines():
            mm = _COLL_RE.search(line)
            if not mm:
                continue
            kind = mm.group(2).lower()
            totals[kind] = totals.get(kind, 0.0) + float(_type_bytes(mm.group(1))) * mult
    return totals


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_cfg: AdamWConfig = AdamWConfig(), style: str = "tp",
               pad_vocab: bool = False):
    """Lower + compile one cell; returns (record dict, compiled)."""
    cfg = get_config(arch)
    if pad_vocab and cfg.vocab_size % 128:
        # pad the vocab to a TP-shardable multiple (padded logits rows are
        # never labelled; standard practice, counted in the FLOPs honestly)
        cfg = dataclasses.replace(cfg, vocab_size=-(-cfg.vocab_size // 128) * 128)
    if shape_name not in cfg.shapes():
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long-context cell skipped: full-attention arch "
                          "(DESIGN.md §4)"}, None
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq, gb, kind = SHAPES[shape_name]
    t0 = time.time()

    def ns(tree):  # PartitionSpec tree -> NamedSharding tree
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    with mesh_context(mesh, style=style):
        params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_specs = ns(shard_params_pspecs(params_abs, mesh))
        if kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            o_specs = AdamWState(step=ns(P()), m=p_specs, v=p_specs)
            batch_abs = S.batch_abstract(cfg, shape_name, "train")
            b_specs = ns(S.batch_pspecs(cfg, shape_name, "train", mesh))
            step = make_train_step(model, opt_cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_specs, o_specs, b_specs),
                out_shardings=(p_specs, o_specs,
                               ns({"loss": P(), "tokens": P(), "grad_norm": P()})),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif kind == "prefill":
            batch_abs = S.batch_abstract(cfg, shape_name, "prefill")
            raw_b = S.batch_pspecs(cfg, shape_name, "prefill", mesh)
            b_specs = ns(raw_b)
            step = make_prefill_step(model)
            v_ax = "model" if cfg.vocab_size % (512 if multi_pod else 256) == 0 or \
                cfg.vocab_size % 16 == 0 else None
            logits_spec = ns(P(raw_b["tokens"][0], None, v_ax))
            jitted = jax.jit(step, in_shardings=(p_specs, b_specs),
                             out_shardings=logits_spec)
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            cache_abs, tok_abs = S.decode_abstract(cfg, model, shape_name)
            raw_c, t_spec_raw = S.decode_pspecs(cfg, cache_abs, shape_name, mesh)
            c_specs, t_spec = ns(raw_c), ns(t_spec_raw)
            step = make_serve_step(model)
            jitted = jax.jit(step, in_shardings=(p_specs, c_specs, t_spec),
                             out_shardings=(t_spec, c_specs),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, tok_abs)
        compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    hc = hlo_cost.analyze(hlo)

    n_chips = 512 if multi_pod else 256
    mesh_shape = (2, 16, 16) if multi_pod else (16, 16)
    flops_dev = hc.flops
    bytes_dev = hc.memory_traffic
    coll_bytes = hc.total_collective_bytes
    eff_mesh = mesh_shape if style != "fsdp" else (n_chips, 1)
    if kind == "decode":
        am = analytic_memory(cfg, shape_name, kind, eff_mesh,
                             cache_abs=cache_abs, cache_specs=raw_c, style=style)
    else:
        am = analytic_memory(cfg, shape_name, kind, eff_mesh, style=style)
    record = {
        "arch": arch, "shape": shape_name, "kind": kind, "style": style,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_chips": n_chips,
        "seq": seq, "global_batch": gb,
        "compile_seconds": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "bytes_written_per_device": hc.bytes_written,
            "dot_operand_bytes": hc.dot_operand_bytes,
            "xla_cost_analysis_flops_body_once": float(cost.get("flops", 0.0)),
            "xla_cost_analysis_bytes_body_once": float(cost.get("bytes accessed", 0.0)),
            "unknown_trip_whiles": hc.unknown_trip_whiles,
        },
        "collectives_bytes": dict(hc.collective_bytes),
        "trip_counts": while_trip_counts(hlo),
        "analytic_memory": am,
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": am["traffic_bytes"] / HBM_BW,
            "memory_s_hlo_upper": bytes_dev / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
        },
    }
    rf = record["roofline"]
    record["roofline"]["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: rf[k])
    record["roofline"]["step_s_lower_bound"] = max(
        rf["compute_s"], rf["memory_s"], rf["collective_s"])
    return record, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--style", default="tp",
                    choices=["tp", "tp_sp", "fsdp", "serve"])
    ap.add_argument("--pad-vocab", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    tag = f"{args.arch}_{args.shape}_{args.mesh}".replace(".", "_").replace("/", "_")
    if args.style != "tp":
        tag += f"_{args.style}"
    try:
        record, compiled = lower_cell(args.arch, args.shape,
                                      args.mesh == "multipod", style=args.style,
                                      pad_vocab=args.pad_vocab)
        if args.save_hlo and compiled is not None:
            (outdir / f"{tag}.hlo.txt").write_text(compiled.as_text())
    except Exception as e:  # record failures — they are bugs to fix
        record = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    (outdir / f"{tag}.json").write_text(json.dumps(record, indent=2))
    if "error" in record:
        print(f"FAIL {tag}: {record['error'][:200]}")
        raise SystemExit(1)
    if record.get("skipped"):
        print(f"SKIP {tag}: {record['reason']}")
        return
    rf = record["roofline"]
    print(f"OK {tag}: compile={record['compile_seconds']}s "
          f"peak={record['memory']['peak_bytes']/2**30:.2f}GiB/dev "
          f"compute={rf['compute_s']*1e3:.2f}ms mem={rf['memory_s']*1e3:.2f}ms "
          f"coll={rf['collective_s']*1e3:.2f}ms -> {rf['bottleneck']}")


if __name__ == "__main__":
    main()
