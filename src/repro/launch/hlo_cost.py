"""Structural cost model over post-SPMD compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
under-reports scan-over-layers models by ~L x. This walker parses the HLO
module, multiplies every computation by the product of enclosing
``known_trip_count`` values, and accumulates:

  * flops          — 2 * |result| * |contracted dims| for every dot
  * bytes          — materialized result bytes of top-level (non-fusion-
                     internal) instructions: a proxy for HBM write traffic;
                     reads ~ equal writes for elementwise chains, and dot
                     operand reads are counted explicitly
  * collectives    — result bytes per collective kind

All values are PER DEVICE (post-SPMD shapes).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z0-9\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "iota", "after-all", "partition-id", "replica-id"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


def _parse_computations(hlo: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    current: list[Instr] | None = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line):
            current = []
            comps[hdr.group(1)] = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            current.append(Instr(*m.groups()))
    return comps


def _dot_flops(instr: Instr, types: dict[str, str]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dim sizes)."""
    ops = re.findall(r"%([\w.\-]+)", instr.rest.split(")")[0])
    if not ops:
        return 0.0
    lhs_type = types.get(ops[0], "")
    dims_list = _shape_dims(lhs_type)
    if not dims_list:
        return 0.0
    lhs_dims = dims_list[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    result = 1
    rdims = _shape_dims(instr.type_str)
    if rdims:
        for d in rdims[0][1]:
            result *= d
    return 2.0 * result * contract


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_written: float = 0.0
    dot_operand_bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    unknown_trip_whiles: int = 0

    @property
    def memory_traffic(self) -> float:
        """HBM traffic proxy: writes + elementwise reads (~writes) + dot reads."""
        return 2.0 * self.bytes_written + self.dot_operand_bytes

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze(hlo: str) -> HloCost:
    comps = _parse_computations(hlo)
    cost = HloCost()
    visited_guard: set[tuple[str, int]] = set()

    def walk(comp_name: str, mult: float, top_level: bool):
        instrs = comps.get(comp_name)
        if instrs is None:
            return
        types = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            op = ins.opcode
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                trip = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
                t = int(trip.group(1)) if trip else 1
                if not trip:
                    cost.unknown_trip_whiles += 1
                if body:
                    walk(body.group(1), mult * t, top_level)
                if cond:
                    walk(cond.group(1), mult * t, False)
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "select-and-scatter", "sort"):
                # walk called computations for dot flops only
                for sub in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.rest):
                    walk(sub, mult, False)
            if op == "conditional":
                for sub in re.findall(r"computations=\{([^}]*)\}", ins.rest):
                    for nm in re.findall(r"%?([\w.\-]+)", sub):
                        walk(nm, mult, False)
            if op == "dot":
                f = _dot_flops(ins, types)
                cost.flops += mult * f
                opnames = re.findall(r"%([\w.\-]+)", ins.rest.split(")")[0])
                for o in opnames[:2]:
                    cost.dot_operand_bytes += mult * _type_bytes(types.get(o, ""))
            if op == "convolution":
                # depthwise/small convs only in this codebase: approximate as
                # 2 * result * kernel_elems
                kernel = 1
                opnames = re.findall(r"%([\w.\-]+)", ins.rest.split(")")[0])
                if len(opnames) > 1:
                    kd = _shape_dims(types.get(opnames[1], ""))
                    if kd:
                        for d in kd[0][1]:
                            kernel *= d
                res = _type_bytes(ins.type_str) / max(
                    _DTYPE_BYTES.get(_shape_dims(ins.type_str)[0][0], 4), 1) \
                    if _shape_dims(ins.type_str) else 0
                cost.flops += mult * 2.0 * res * min(kernel, 1024)
            if op in _COLLECTIVES:
                kind = op.replace("-start", "")
                nbytes = _type_bytes(ins.type_str)
                # TPU projection: CPU XLA promotes bf16 payloads to f32
                # around collectives (promoted reducers; converts commuted
                # across gathers/reduces). When the payload is semantically
                # bf16 (producer is a convert) count it at bf16 — a TPU
                # build keeps these collectives in bf16 on the wire.
                if "f32" in ins.type_str:
                    opnames = re.findall(r"%([\w.\-]+)", ins.rest.split(")")[0])
                    producer_is_convert = any("convert" in o for o in opnames)
                    if "promoted" in ins.rest or producer_is_convert:
                        nbytes //= 2
                # ring-algorithm wire bytes per device:
                #   all-reduce:      2 (n-1)/n * payload   (payload = result)
                #   all-gather:        (n-1)/n * result
                #   reduce-scatter:    (n-1)/n * input  (= result * n)
                #   all-to-all:        (n-1)/n * result
                #   collective-permute: result
                g = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.rest)
                n = int(g.group(2)) if g else 2
                frac = (n - 1) / max(n, 1)
                if kind == "all-reduce":
                    wire = 2.0 * frac * nbytes
                elif kind == "reduce-scatter":
                    wire = frac * nbytes * n
                elif kind == "collective-permute":
                    wire = float(nbytes)
                else:
                    wire = frac * nbytes
                cost.collective_bytes[kind] += mult * wire
            if top_level and op not in _SKIP_BYTES_OPS:
                cost.bytes_written += mult * _type_bytes(ins.type_str)

    entry = None
    for name in comps:
        if re.search(r"^ENTRY", "\n".join(l for l in hlo.splitlines()
                                          if name in l and "ENTRY" in l), re.M):
            entry = name
            break
    if entry is None:  # fall back: computation named main-ish or the last one
        cands = [n for n in comps if n.startswith("main")]
        entry = cands[0] if cands else list(comps)[-1]
    walk(entry, 1.0, True)
    return cost
