"""Optimizer substrate: AdamW + schedules + global-norm clipping +
gradient compression (top-k / int8 with error feedback)."""
from .adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from .schedule import cosine_schedule, linear_warmup
from .compression import (CompressionState, compress_topk, decompress_topk,
                          compressed_allreduce_init, int8_compress, int8_decompress)

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "cosine_schedule", "linear_warmup", "CompressionState",
           "compress_topk", "decompress_topk", "compressed_allreduce_init",
           "int8_compress", "int8_decompress"]
