"""Gradient compression for slow (cross-pod) links, with error feedback.

Two codecs:
  * top-k sparsification — keep the k largest-magnitude entries per tensor,
    accumulate the residual locally (error feedback, Stich et al.) so the
    compression bias vanishes over steps;
  * int8 linear quantization — per-tensor scale, ~4x wire reduction.

Intended use at scale: compress the cross-pod segment of the gradient
all-reduce (in-pod reduction stays exact); see launch/train.py. On the
dry-run mesh this is exercised by tests and the e2e example.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any  # error-feedback accumulator, same structure as grads


def compressed_allreduce_init(grads) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads))


def compress_topk(x: jax.Array, frac: float = 0.05):
    """Returns (values, flat_indices) keeping ceil(frac * n) entries."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def decompress_topk(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    n = 1
    for d in shape:
        n *= d
    return jnp.zeros((n,), jnp.float32).at[idx].set(values).reshape(shape)


def topk_roundtrip_with_feedback(g: jax.Array, residual: jax.Array,
                                 frac: float = 0.05):
    """Error-feedback top-k: compress (g + residual), return (g_hat, new_res)."""
    corrected = g.astype(jnp.float32) + residual
    vals, idx = compress_topk(corrected, frac)
    g_hat = decompress_topk(vals, idx, g.shape)
    return g_hat.astype(g.dtype), corrected - g_hat


def int8_compress(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
