"""AdamW with decoupled weight decay and global-norm clipping (pure pytree).

Moment tensors inherit the parameter sharding (2-D FSDP x TP) so optimizer
state is ZeRO-1-sharded for free under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    m = jax.tree.map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g, state.m, grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * g * g, state.v, grads)

    def upd(p, mm, vv):
        mhat = mm / b1c
        vhat = vv / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), {"grad_norm": gnorm}
