"""Cross-pod collectives: int8-compressed gradient all-reduce.

At multi-pod scale the `pod` axis is the slow link (DCN / inter-pod ICI).
The in-pod reduction stays exact (bf16); across pods each pod exchanges an
int8-quantized copy of its partial (4x wire reduction vs bf16, 8x vs f32)
and decompresses locally. With error feedback at the optimizer level
(repro.optim.compression) the quantization bias vanishes over steps.

Implemented with jax.shard_map over the `pod` axis only — `data`/`model`
stay under GSPMD, so this composes with any in-pod layout. Usage in a train
step (multi-pod mesh):

    grads = cross_pod_compressed_allreduce(grads, mesh)   # after in-pod RS
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _int8_pack(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def cross_pod_compressed_allreduce(tree, mesh: Mesh):
    """Sum a pytree across the `pod` axis with int8 payloads on the wire.

    Each leaf is assumed to hold this pod's partial contribution (replicated
    within the pod or sharded over data/model — both compose). Returns the
    cross-pod sum with the same shardings.
    """
    if "pod" not in mesh.axis_names:
        return tree

    def leaf_sync(x):
        q, scale = _int8_pack(x)
        qs = jax.lax.all_gather(q, "pod")  # int8 on the slow link
        ss = jax.lax.all_gather(scale, "pod")
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
        return jnp.sum(deq, axis=0).astype(x.dtype)

    def sync(t):
        return jax.tree.map(leaf_sync, t)

    fn = jax.shard_map(
        sync, mesh=mesh,
        in_specs=jax.tree.map(lambda _: P("pod"), tree),
        out_specs=jax.tree.map(lambda _: P(), tree),
        axis_names={"pod"}, check_vma=False)
    # Note: in_specs P('pod') treats the leading dim as stacked per-pod
    # partials; most callers instead hold identical-shape partials per pod —
    # see cross_pod_sum_partials below for that layout.
    return fn(tree)


def cross_pod_sum_partials(tree, mesh: Mesh):
    """Variant for the common case: every pod holds a same-shape partial
    (e.g. its gradient shard); leaves are replicated across `pod` from
    GSPMD's point of view but numerically different per pod is NOT
    expressible — so this applies where the caller explicitly maintains
    per-pod values inside a shard_map region."""

    def leaf_sync(x):
        q, scale = _int8_pack(x)
        qs = jax.lax.all_gather(q, "pod")
        ss = jax.lax.all_gather(scale, "pod")
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
        return jnp.sum(deq, axis=0).astype(x.dtype)

    return jax.tree.map(leaf_sync, tree)
