"""Distribution layer: mesh-aware sharding rules and collective helpers."""
from .sharding import (batch_axes, constrain_act, current_mesh, mesh_context,
                       param_pspec, shard_params, shard_params_pspecs)

__all__ = ["batch_axes", "constrain_act", "current_mesh", "mesh_context",
           "param_pspec", "shard_params", "shard_params_pspecs"]
