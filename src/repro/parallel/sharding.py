"""Sharding rules: 2-D (FSDP x TP) parameter layout + activation constraints.

Mesh axes:
  pod    cross-pod data parallelism (multi-pod mesh only; params replicated
         across pods — optimizer state is NOT sharded over the slow pod axis)
  data   in-pod data parallelism; also hosts the ZeRO-1 shard of params/opt
  model  tensor parallelism (heads / ffn / vocab / d_inner)

The paper mapping (DESIGN.md §2): each `data`-axis slice group is one EC
(ML worker); Cocktail's x/y/z decisions set the per-EC batch composition and
sample weights consumed by the weighted-psum aggregation (eq. 15).
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_MESH: Optional[Mesh] = None
# Parallelism style (see EXPERIMENTS.md §Perf):
#   "tp"    baseline: batch on (pod, data); TP on model; ZeRO over data
#   "fsdp"  batch over ALL axes; weights fully gathered per layer (ZeRO-3);
#           no tensor parallelism — trades small weight all-gathers for the
#           large TP activation all-reduces
#   "serve" inference layout: weights TP-sharded on model, REPLICATED over
#           data (no per-token FSDP gathers); decode/prefill only
_STYLE: str = "tp"


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], style: str = "tp"):
    """Install `mesh` (+ parallelism style) for model-code constraints."""
    global _MESH, _STYLE
    prev, prev_style = _MESH, _STYLE
    _MESH, _STYLE = mesh, style
    try:
        yield mesh
    finally:
        _MESH, _STYLE = prev, prev_style


def current_mesh() -> Optional[Mesh]:
    return _MESH


def current_style() -> str:
    return _STYLE


def batch_axes(mesh: Mesh):
    if _STYLE == "fsdp":
        return tuple(mesh.axis_names)  # batch over every axis
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def constrain_act(x: jax.Array, spec: tuple) -> jax.Array:
    """Constrain an activation. `spec` entries: 'batch' -> DP axes,
    'model' -> TP axis, 'seq' -> TP axis under the tp_sp style (sequence-
    sharded remat carries, Korthikanti-style sequence parallelism) else
    unsharded, None -> unsharded. No-op without a mesh context."""
    if _MESH is None:
        return x

    def resolve(entry):
        if entry == "batch":
            return batch_axes(_MESH)
        if entry == "seq":
            return "model" if _STYLE == "tp_sp" else None
        if entry == "model" and _STYLE == "fsdp":
            return None  # model axis belongs to the batch under fsdp
        return entry

    resolved = tuple(resolve(e) for e in spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*resolved)))


def kv_layout(n_kv_heads: int) -> str:
    """Decode KV-cache layout policy (must mirror launch/specs.cache_pspecs):
    'heads' when the kv head count shards exactly on the model axis, else
    'seq' (sequence-sharded cache + model-replicated q)."""
    if _MESH is None:
        return "heads"
    msz = _MESH.shape.get("model", 1)
    return "heads" if (n_kv_heads % msz == 0 and n_kv_heads >= msz) else "seq"


def dp_group_count(n_items: int) -> int:
    """Static DP shard count for shard-local batch grouping (MoE dispatch):
    the number of (pod x data) shards if it divides n_items, else 1."""
    if _MESH is None:
        return 1
    dp = 1
    for a in batch_axes(_MESH):
        dp *= _MESH.shape.get(a, 1)
    return dp if (n_items % dp == 0 and n_items >= dp) else 1


def gather_fsdp(w: jax.Array, spec: tuple) -> jax.Array:
    """FSDP weight gather: constrain a (ZeRO-sharded) weight to its TP-only
    layout at the use site, so the partitioner inserts one small bf16
    all-gather over the data axis instead of all-reducing the (much larger)
    activation partial-sums of a matmul with a sharded contracting dim.

    `spec` names only the TP placement, e.g. (None, 'model', None) for a
    (D, H, hd) projection. §Perf iteration 1 — see EXPERIMENTS.md.
    """
    if _MESH is None:
        return w
    if _STYLE == "fsdp":  # ZeRO-3: gather the whole weight at use
        spec = tuple(None for _ in spec)
    # tp_sp behaves like tp for weights
    if any(d % _MESH.shape.get("model", 1) for d, s in zip(w.shape, spec) if s == "model"):
        spec = tuple(None for _ in spec)  # not TP-divisible: fully gather
    # pin the (bf16) cast BEFORE the gather: without the barrier XLA commutes
    # convert/all-gather and moves f32 master bytes over the wire (2x)
    w = jax.lax.optimization_barrier(w)
    return jax.lax.with_sharding_constraint(w, NamedSharding(_MESH, P(*spec)))


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

# Leaf-name -> partition spec for the *trailing* (non-stacked) dims.
# 'F' = fsdp/ZeRO axis ('data'), 'T' = tensor axis ('model').
_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)embed$", ("T", "F")),  # (V, D): vocab on model
    (r"(^|/)pos_embed$", (None, None)),
    (r"(^|/)(cross_)?w[qkv]$", ("F", "T", None)),  # (D, H, hd): heads on model
    (r"(^|/)b[qkv]$", ("T", None)),  # (H, hd)
    (r"(^|/)(cross_)?wo$", ("T", None, "F")),  # (H, hd, D)
    (r"(^|/)w_(gate|up)$", ("F", "T")),  # (D, FF)
    (r"(^|/)w_down$", ("T", "F")),  # (FF, D)
    (r"(^|/)router$", ("F", None)),  # (D, E)
    (r"(^|/)we_(gate|up)$", (None, "F", "T")),  # (E, D, FF)
    (r"(^|/)we_down$", (None, "T", "F")),  # (E, FF, D)
    (r"(^|/)in_proj$", ("F", "T")),  # (D, ...) ssm
    (r"(^|/)conv_w$", ("T", None)),  # (DI, K)
    (r"(^|/)conv_b$", ("T",)),
    (r"(^|/)x_proj$", ("T", None)),  # (DI, R+2N)
    (r"(^|/)dt_proj$", (None, "T")),  # (R, DI)
    (r"(^|/)dt_bias$", ("T",)),
    (r"(^|/)a_log$", ("T", None)),  # (DI, N) or (H,) mamba2
    (r"(^|/)ssm_d$", ("T",)),
    (r"(^|/)out_proj$", ("T", "F")),  # (DI, D)
    (r"(^|/).*norm.*$", None),  # any norm scale/bias: replicated
    (r"(^|/)head$", ("F", "T")),  # (D, V) lm head
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspec(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Resolve the PartitionSpec for one parameter.

    Stacked layer params (path containing 'blocks') get a leading None for
    the layer dim. Dims whose size is not divisible by the assigned mesh axis
    still shard (GSPMD pads), except size-1 dims which are left unsharded.
    """
    stacked = "blocks" in path or "enc_blocks" in path or "dec_blocks" in path
    trailing = shape[1:] if stacked else shape
    spec: Optional[tuple] = None
    leaf = path
    for pat, rule in _RULES:
        if re.search(pat, leaf):
            spec = rule
            break
    if spec is None:
        spec = (None,) * len(trailing)
    if spec is not None and len(spec) != len(trailing):
        # rank mismatch (e.g. bias picked up a matrix rule): replicate
        spec = (None,) * len(trailing)

    ax = {"F": "data", "T": "model", None: None}
    if _STYLE == "serve":  # replicate over data: no FSDP gathers per token
        ax = {"F": None, "T": "model", None: None}
    resolved = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, s in zip(trailing, spec):
        name = ax[s]
        if name is not None and dim % axis_sizes.get(name, 1) != 0:
            name = None  # jit in_shardings require exact divisibility
        resolved.append(name)
    if stacked:
        resolved = [None] + resolved
    return P(*resolved)


def shard_params_pspecs(params, mesh: Mesh):
    """pytree of PartitionSpec matching `params`."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(_path_str(path), leaf.shape, mesh), params)


def shard_params(params, mesh: Mesh):
    """Device-put params according to the rule table (host-side)."""
    specs = shard_params_pspecs(params, mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)
