"""Synthetic non-IID data sources (the CUs of the paper).

Each CU generates samples from its own distribution — the data-skew setting
of the paper. Two generators:

  * ``TokenSource``: LM tokens from a per-CU Zipf distribution over a
    permuted vocab slice (source id recoverable from distribution), used by
    the Cocktail-scheduled LM training examples.
  * ``TrafficSource``: the paper's own testbed task — base-station traffic
    time series (diurnal + weekly structure + noise); samples are
    (4 consecutive records -> next record) exactly as Sec. IV-A.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenSource:
    cu_id: int
    vocab_size: int
    seq_len: int
    zipf_a: float = 1.2
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed * 1000 + self.cu_id)
        # per-CU vocab permutation -> distinct unigram distributions
        self._perm = rng.permutation(self.vocab_size)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** -self.zipf_a
        self._p = p / p.sum()
        self._rng = rng

    def sample(self, n: int) -> np.ndarray:
        """n sequences of tokens, (n, seq_len) int32."""
        raw = self._rng.choice(self.vocab_size, size=(n, self.seq_len), p=self._p)
        return self._perm[raw].astype(np.int32)


@dataclasses.dataclass
class TrafficSource:
    """Paper testbed data generation: one CU covers a community of base
    stations; each record is normalized traffic; a sample is a history
    window of 4 records + the next record as the label."""

    cu_id: int
    n_stations: int = 90
    history: int = 4
    slot_minutes: int = 5
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed * 7919 + self.cu_id)
        self._phase = rng.uniform(0, 2 * np.pi, self.n_stations)
        self._scale = rng.uniform(0.4, 1.0, self.n_stations)
        # per-CU signature: traffic level and burstiness differ by community
        self._level = rng.uniform(0.2, 0.8)
        self._noise = rng.uniform(0.02, 0.12)
        self._rng = rng
        self._t = 0

    def _series(self, t: np.ndarray, station: np.ndarray) -> np.ndarray:
        day = 2 * np.pi * t * self.slot_minutes / (24 * 60)
        base = self._level + 0.35 * self._scale[station] * np.sin(day + self._phase[station])
        base = base + 0.1 * np.sin(2 * day + self._phase[station] * 0.5)
        noise = self._rng.normal(0, self._noise, size=t.shape)
        return np.clip(base + noise, 0.0, 1.0)

    def sample(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (x (n, history), y (n,)) float32."""
        stations = self._rng.integers(0, self.n_stations, n)
        starts = self._t + self._rng.integers(0, 288, n)
        offs = np.arange(self.history + 1)
        tt = starts[:, None] + offs[None, :]
        vals = self._series(tt, stations[:, None].repeat(self.history + 1, axis=1))
        self._t += 1
        return vals[:, :-1].astype(np.float32), vals[:, -1].astype(np.float32)
