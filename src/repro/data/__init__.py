"""Data substrate: non-IID CU sources + the Cocktail decision->batch bridge."""
from .sampler import CocktailSampler
from .sources import TokenSource, TrafficSource

__all__ = ["CocktailSampler", "TokenSource", "TrafficSource"]
