"""CocktailSampler: the bridge from scheduler decisions to training batches.

Each slot the core scheduler emits x[i,j] / y[i,j,k] (samples of CU i trained
at EC j). With ECs mapped to data-parallel groups (DESIGN.md §2), the sampler

  1. converts the per-EC trained counts into an integer batch composition
     (how many sequences of each source each EC's shard trains this step),
  2. draws that many sequences from each ``TokenSource``,
  3. emits per-sample weights so the weighted-mean loss implements the
     |D_j|-weighted parameter-server aggregation (paper eq. 15).

The same machinery also drives the traffic-prediction testbed task (fig. 7).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import CocktailConfig, Decision
from repro.data.sources import TokenSource


@dataclasses.dataclass
class CocktailSampler:
    cfg: CocktailConfig
    sources: Sequence[TokenSource]
    batch_per_ec: int  # sequences each EC contributes to the global batch
    seed: int = 0

    def __post_init__(self):
        assert len(self.sources) == self.cfg.n_cu
        self._rng = np.random.default_rng(self.seed)

    def composition(self, decision: Decision) -> np.ndarray:
        """(M, N) integer counts: sequences from CU i trained by EC j this
        step, scaled so each EC trains at most batch_per_ec sequences and
        proportions follow trained_at = x + sum_j y."""
        x = np.asarray(decision.x, np.float64)
        y = np.asarray(decision.y, np.float64)
        trained_at = x + y.sum(axis=1)  # (N, M)
        comp = np.zeros((self.cfg.n_ec, self.cfg.n_cu), np.int64)
        for j in range(self.cfg.n_ec):
            col = trained_at[:, j]
            tot = col.sum()
            if tot <= 0:
                continue
            frac = col / tot * self.batch_per_ec
            cnt = np.floor(frac).astype(np.int64)
            rem = self.batch_per_ec - cnt.sum()
            if rem > 0:
                order = np.argsort(-(frac - cnt))
                cnt[order[:rem]] += 1
            comp[j] = cnt
        return comp

    def sample(self, decision: Decision) -> dict:
        """Build the global batch for one step.

        Returns dict(tokens (M*B, S), labels, weights (M*B,), source_ids,
        ec_ids). weights scale each EC's samples by its |D_j| share (eq. 15);
        ECs that trained nothing this slot get zero-weight filler samples.
        """
        comp = self.composition(decision)  # (M, N)
        trained = np.asarray(decision.x, np.float64) + \
            np.asarray(decision.y, np.float64).sum(axis=1)
        d_j = trained.sum(axis=0)  # |D_j|
        mean_d = d_j.mean() if d_j.sum() > 0 else 1.0

        toks, weights, src_ids, ec_ids = [], [], [], []
        for j in range(self.cfg.n_ec):
            w_j = d_j[j] / max(mean_d, 1e-9)
            n_filled = 0
            for i in range(self.cfg.n_cu):
                n = int(comp[j, i])
                if n == 0:
                    continue
                toks.append(self.sources[i].sample(n))
                weights.extend([w_j] * n)
                src_ids.extend([i] * n)
                ec_ids.extend([j] * n)
                n_filled += n
            if n_filled < self.batch_per_ec:  # zero-weight padding
                pad = self.batch_per_ec - n_filled
                toks.append(self.sources[0].sample(pad))
                weights.extend([0.0] * pad)
                src_ids.extend([0] * pad)
                ec_ids.extend([j] * pad)
        tokens = np.concatenate(toks, axis=0)
        labels = np.roll(tokens, -1, axis=1).copy()
        labels[:, -1] = -1
        return {
            "tokens": tokens.astype(np.int32),
            "labels": labels.astype(np.int32),
            "weights": np.asarray(weights, np.float32),
            "source_ids": np.asarray(src_ids, np.int32),
            "ec_ids": np.asarray(ec_ids, np.int32),
        }
