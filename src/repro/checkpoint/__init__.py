"""Fault-tolerant checkpointing: atomic npz snapshots, auto-resume,
elastic resharding across mesh shapes."""
from .checkpoint import (CheckpointManager, latest_step, restore, save,
                         restore_sharded)

__all__ = ["CheckpointManager", "latest_step", "restore", "save",
           "restore_sharded"]
