"""Checkpointing substrate.

Design (node-failure tolerant):
  * one ``step_<N>.npz`` per snapshot, written to a tmp file then atomically
    renamed — a crash mid-write never corrupts the latest checkpoint;
  * ``latest_step``/auto-resume: the training driver restarts from the
    newest complete snapshot after any failure (see launch/train.py);
  * **elastic resharding**: arrays are stored as full host arrays keyed by
    pytree path; ``restore_sharded`` device_puts them under ANY mesh/sharding
    — restarting on a different topology (scale up/down after node loss)
    needs no conversion step;
  * a retention window bounds disk usage.

At real multi-pod scale the npz container would be replaced by a parallel
object store writer per host shard; the atomic-rename + manifest protocol
and the resharding path are the load-bearing parts and are what tests cover.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs "
                             f"template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(path: str | pathlib.Path, step: int, tree: Any,
         extra: Optional[dict] = None) -> pathlib.Path:
    """Atomic snapshot: write tmp in same dir, fsync, rename."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    if extra:
        flat["__meta__"] = np.frombuffer(
            json.dumps(extra).encode(), dtype=np.uint8).copy()
    final = path / f"step_{step:010d}.npz"
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return final


def latest_step(path: str | pathlib.Path) -> Optional[int]:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    steps = [int(m.group(1)) for f in path.iterdir()
             if (m := re.fullmatch(r"step_(\d+)\.npz", f.name))]
    return max(steps) if steps else None


def restore(path: str | pathlib.Path, step: int, template: Any):
    """Load a snapshot as host numpy arrays shaped like `template`."""
    with np.load(pathlib.Path(path) / f"step_{step:010d}.npz") as z:
        flat = {k: z[k] for k in z.files if k != "__meta__"}
        meta = None
        if "__meta__" in z.files:
            meta = json.loads(bytes(z["__meta__"]).decode())
    return _unflatten(template, flat), meta


def restore_sharded(path, step, template, shardings):
    """Elastic restore: place each leaf under `shardings` (any mesh shape —
    the snapshot stores full arrays, so scaling the cluster up or down
    between runs is transparent)."""
    host_tree, meta = restore(path, step, template)
    placed = jax.tree.map(lambda a, s: jax.device_put(a, s), host_tree, shardings)
    return placed, meta


@dataclasses.dataclass
class CheckpointManager:
    """save-every-N + retention + auto-resume convenience wrapper."""

    directory: str
    every_steps: int = 50
    keep: int = 3

    def maybe_save(self, step: int, tree: Any, extra: Optional[dict] = None) -> bool:
        if step % self.every_steps:
            return False
        save(self.directory, step, tree, extra)
        self._gc()
        return True

    def _gc(self):
        path = pathlib.Path(self.directory)
        snaps = sorted(f for f in path.iterdir()
                       if re.fullmatch(r"step_\d+\.npz", f.name))
        for f in snaps[:-self.keep]:
            f.unlink()

    def resume(self, template: Any, shardings=None):
        """Returns (tree, meta, step) from the newest snapshot, or None."""
        step = latest_step(self.directory)
        if step is None:
            return None
        if shardings is not None:
            tree, meta = restore_sharded(self.directory, step, template, shardings)
        else:
            tree, meta = restore(self.directory, step, template)
        return tree, meta, step
