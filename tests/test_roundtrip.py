"""Round-trip coverage for the config/params plumbing: split_config,
stack_slice_params / fleet.unstack, and the mask fields added for ragged
fleets — previously exercised only indirectly through run()."""
import dataclasses

import numpy as np
import pytest

from repro.core import (DS, CocktailConfig, ShapeConfig, SliceParams,
                        entity_masks, init_state, split_config,
                        stack_slice_params)
from repro.core.fleet import trim_state, unstack

CFG = CocktailConfig(n_cu=5, n_ec=3, eps=0.2, pair_iters=12, seed=9,
                     zeta=np.array([100.0, 200.0, 300.0, 400.0, 500.0]),
                     f_base=(9000.0, 15000.0, 21000.0))


def _assert_params_equal(a: SliceParams, b: SliceParams):
    for field in SliceParams._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=field)


def test_split_config_cocktail():
    shape, params = split_config(CFG)
    assert shape == ShapeConfig(n_cu=5, n_ec=3, pair_iters=12)
    _assert_params_equal(params, CFG.params)
    # masks are materialized all-ones at the true shape
    np.testing.assert_array_equal(np.asarray(params.cu_mask), np.ones(5))
    np.testing.assert_array_equal(np.asarray(params.ec_mask), np.ones(3))


def test_split_config_explicit_pair_passthrough():
    shape, params = split_config(CFG)
    shape2, params2 = split_config(shape, params)
    assert shape2 is shape and params2 is params
    # explicit params override the config's own
    other = dataclasses.replace(CFG, eps=0.5).params
    _, p3 = split_config(CFG, other)
    assert float(p3.eps) == 0.5


def test_split_config_shape_without_params_raises():
    with pytest.raises(TypeError):
        split_config(CFG.shape)


@pytest.mark.parametrize("k", [1, 3])
def test_stack_unstack_roundtrip(k):
    cfgs = [dataclasses.replace(CFG, seed=s, eps=0.1 + 0.05 * s)
            for s in range(k)]
    stacked = stack_slice_params([c.params for c in cfgs])
    # every leaf gained exactly one leading K axis — masks included
    for field in SliceParams._fields:
        leaf = getattr(stacked, field)
        single = getattr(cfgs[0].params, field)
        assert leaf.shape == (k,) + single.shape, field
    for s, cfg in enumerate(cfgs):
        _assert_params_equal(unstack(stacked, s), cfg.params)


def test_stack_unstack_roundtrip_padded():
    pad = ShapeConfig(n_cu=8, n_ec=4, pair_iters=12)
    small = SliceParams.from_config(CFG, pad_shape=pad)
    big = SliceParams.from_config(
        dataclasses.replace(CFG, n_cu=8, n_ec=4, zeta=500.0,
                            f_base=10000.0), pad_shape=pad)
    stacked = stack_slice_params([small, big])
    _assert_params_equal(unstack(stacked, 0), small)
    _assert_params_equal(unstack(stacked, 1), big)
    np.testing.assert_array_equal(np.asarray(stacked.cu_mask),
                                  [[1] * 5 + [0] * 3, [1] * 8])


def test_padded_params_real_block_matches_unpadded():
    pad = ShapeConfig(n_cu=9, n_ec=5, pair_iters=12)
    p = SliceParams.from_config(CFG, pad_shape=pad)
    ref = CFG.params
    for field in ("zeta", "proportions", "delta_lo", "delta_hi"):
        np.testing.assert_array_equal(np.asarray(getattr(p, field))[:5],
                                      np.asarray(getattr(ref, field)),
                                      err_msg=field)
        assert (np.asarray(getattr(p, field))[5:] == 0).all(), field
    np.testing.assert_array_equal(np.asarray(p.f_base)[:3],
                                  np.asarray(ref.f_base))
    assert (np.asarray(p.f_base)[3:] == 0).all()
    cu, ec = entity_masks(p)
    np.testing.assert_array_equal(np.asarray(cu), [1] * 5 + [0] * 4)
    np.testing.assert_array_equal(np.asarray(ec), [1] * 3 + [0] * 2)


def test_entity_masks_default_all_ones():
    # hand-built params without masks (pre-ragged pytrees) default to ones
    p = CFG.params._replace(cu_mask=None, ec_mask=None)
    cu, ec = entity_masks(p)
    np.testing.assert_array_equal(np.asarray(cu), np.ones(5))
    np.testing.assert_array_equal(np.asarray(ec), np.ones(3))


def test_trim_state_inverts_padded_init():
    """init at the pad shape, trimmed, equals init at the true shape."""
    pad = ShapeConfig(n_cu=8, n_ec=4, pair_iters=12)
    padded = init_state(pad, SliceParams.from_config(CFG, pad_shape=pad),
                        seed=CFG.seed)
    ref = init_state(CFG)
    tr = trim_state(padded, CFG.shape)
    np.testing.assert_array_equal(np.asarray(tr.queues.q),
                                  np.asarray(ref.queues.q))
    np.testing.assert_array_equal(np.asarray(tr.queues.r),
                                  np.asarray(ref.queues.r))
    np.testing.assert_array_equal(np.asarray(tr.mults.mu),
                                  np.asarray(ref.mults.mu))
    np.testing.assert_array_equal(np.asarray(tr.uploaded),
                                  np.asarray(ref.uploaded))
    # padded region carries no backlog and no queue price
    assert (np.asarray(padded.queues.q)[5:] == 0).all()
    assert (np.asarray(padded.mults.mu)[5:] == 0).all()
