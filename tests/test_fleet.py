"""FleetEngine: K-slice vmapped scheduling vs the single-slice reference.

The batch-first contract: a fleet of K=1 reproduces ``datasche.run`` (same
compiled math, just vmapped), and a heterogeneous K-slice fleet matches K
sequential single-slice runs slice-for-slice.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DS, LDS, NO_LSA, NO_SDC, DS_EXACT, CocktailConfig,
                        FleetEngine, ShapeConfig, SliceParams,
                        stack_slice_params, run)
from repro.core import metrics
from repro.core.fleet import unstack

BASE = CocktailConfig(n_cu=8, n_ec=3, eps=0.1, pair_iters=15, seed=7,
                      f_base=(8000.0, 20000.0, 12000.0))
SLOTS = 12


def _assert_state_close(fleet_state, k, ref_state):
    sk = unstack(fleet_state, k)
    for name in ("q", "r", "omega"):
        np.testing.assert_allclose(np.asarray(getattr(sk.queues, name)),
                                   np.asarray(getattr(ref_state.queues, name)),
                                   rtol=1e-4, atol=1e-2, err_msg=name)
    np.testing.assert_allclose(float(sk.total_cost), float(ref_state.total_cost),
                               rtol=1e-4)
    np.testing.assert_allclose(float(sk.total_trained), float(ref_state.total_trained),
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sk.mults.mu), np.asarray(ref_state.mults.mu),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("spec", [DS, LDS, NO_LSA], ids=lambda s: s.name)
def test_k1_matches_single_slice(spec):
    st_ref, recs_ref = run(BASE, spec, SLOTS)
    eng = FleetEngine.from_configs([BASE], spec)
    st, recs = eng.run(SLOTS)
    # records are time-major (T, K)
    assert recs.cost.shape == (SLOTS, 1)
    np.testing.assert_allclose(np.asarray(recs.cost[:, 0]),
                               np.asarray(recs_ref.cost), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(recs.skew[:, 0]),
                               np.asarray(recs_ref.skew), rtol=1e-3, atol=1e-5)
    _assert_state_close(st, 0, st_ref)


def test_k3_heterogeneous_matches_sequential():
    cfgs = [
        BASE,
        dataclasses.replace(BASE, eps=0.2, zeta=np.array([300.0] * 4 + [900.0] * 4),
                            seed=11),
        dataclasses.replace(BASE, c_base=100.0, p_base=300.0,
                            f_base=(16000.0, 16000.0, 16000.0), seed=12),
    ]
    eng = FleetEngine.from_configs(cfgs, DS)
    assert eng.n_slices == 3
    st, recs = eng.run(SLOTS)
    assert recs.cost.shape == (SLOTS, 3)
    for k, cfg in enumerate(cfgs):
        st_ref, recs_ref = run(cfg, DS, SLOTS)
        np.testing.assert_allclose(np.asarray(recs.cost[:, k]),
                                   np.asarray(recs_ref.cost), rtol=1e-4)
        _assert_state_close(st, k, st_ref)
        # per-slice metrics work on the unstacked state
        s = metrics.summary(cfg, eng.slice_state(st, k))
        np.testing.assert_allclose(s["total_trained"], float(st_ref.total_trained),
                                   rtol=1e-4)


def test_single_program_runs_k8():
    """K>=8 heterogeneous fleet executes inside one jitted scan (acceptance
    criterion); every slice makes progress and stays finite. 20 slots: the
    onset of training is realization-dependent (a low-eps slice can spend the
    first ~10 slots only collecting)."""
    cfgs = [dataclasses.replace(BASE, seed=s, zeta=300.0 + 60.0 * s,
                                eps=0.08 + 0.02 * (s % 3))
            for s in range(8)]
    eng = FleetEngine.from_configs(cfgs, DS)
    st, recs = eng.run(20)
    assert recs.cost.shape == (20, 8)
    assert np.isfinite(np.asarray(recs.cost)).all()
    assert (np.asarray(st.total_trained) > 0).all()
    assert np.isfinite(np.asarray(st.queues.q)).all()


def test_fleet_rejects_mixed_shapes_and_exact():
    other = dataclasses.replace(BASE, n_cu=9)
    with pytest.raises(ValueError):
        FleetEngine.from_configs([BASE, other], DS)
    with pytest.raises(ValueError):
        FleetEngine.from_configs([BASE], DS_EXACT)


def test_from_params_roundtrip():
    params = stack_slice_params([BASE.params, dataclasses.replace(BASE, eps=0.3).params])
    eng = FleetEngine.from_params(BASE.shape, params, DS, seeds=(1, 2))
    st, recs = eng.run(4)
    assert recs.cost.shape == (4, 2)
    # eps heterogeneity is live in the stacked pytree
    np.testing.assert_allclose(np.asarray(eng.params.eps), [0.1, 0.3], rtol=1e-6)


def test_sharded_run_matches_unsharded():
    """NamedSharding over the slice axis (1-device mesh on CPU) is a no-op
    numerically."""
    from repro.launch.mesh import make_host_mesh

    cfgs = [BASE, dataclasses.replace(BASE, seed=3, zeta=700.0)]
    eng = FleetEngine.from_configs(cfgs, DS)
    st_plain, _ = eng.run(6)
    mesh = make_host_mesh()
    if 2 % mesh.shape["data"] != 0:
        pytest.skip("slice count not divisible by mesh data axis")
    st_shard, _ = eng.run(6, mesh=mesh)
    np.testing.assert_allclose(np.asarray(st_shard.queues.q),
                               np.asarray(st_plain.queues.q), rtol=1e-5)


def test_batched_greedy_assignment_dispatch():
    """kernels/matching ops accepts a stacked (K, N, M) weight batch."""
    from repro.kernels.matching import ops

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.uniform(-1, 5, (3, 16, 4)), jnp.float32)
    out = ops.greedy_assignment(w)
    assert out.shape == (3, 16, 4)
    for k in range(3):
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(ops.greedy_assignment(w[k])))
