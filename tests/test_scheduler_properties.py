"""Hypothesis property tests on system-level scheduler invariants."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import (DS, LDS, CocktailConfig, init_state, run, step,
                        training_weights, sample_network_state)


@pytest.mark.tier2  # recompiles per random (n_cu, n_ec): heaviest in the suite
@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(2, 4))
@settings(max_examples=8, deadline=None)
def test_invariants_random_topologies(seed, n_cu, n_ec):
    """For random sizes/seeds: queues and multipliers stay nonnegative and
    finite, cost accumulates monotonically, trained samples never exceed
    collected samples (conservation)."""
    cfg = CocktailConfig(n_cu=n_cu, n_ec=n_ec, eps=0.15, pair_iters=15,
                         seed=seed % 97)
    st_, recs = run(cfg, DS, 12)
    q = np.asarray(st_.queues.q)
    r = np.asarray(st_.queues.r)
    for m in (st_.mults.mu, st_.mults.eta, st_.mults.phi, st_.mults.lam):
        m = np.asarray(m)
        assert (m >= 0).all() and np.isfinite(m).all()
    assert (q >= -1e-4).all() and (r >= -1e-4).all()
    costs = np.asarray(recs.cost)
    assert (costs >= -1e-3).all()
    # conservation: total trained <= total collected (uploaded);
    # relative tolerance for f32 accumulation across slots
    up = float(st_.uploaded.sum())
    assert float(st_.total_trained) <= up * (1 + 1e-5) + 1.0


@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_training_weight_identity(seed):
    """gamma[i,j,k] == beta[i,k] + eta[i,j] - eta[i,k] - e[j,k] (eq. 18)."""
    rng = np.random.default_rng(seed)
    n, m = 5, 3
    cfg = CocktailConfig(n_cu=n, n_ec=m, seed=seed % 13)
    state = init_state(cfg)
    key = jax.random.PRNGKey(seed % 1000)
    net = sample_network_state(key, cfg, jnp.asarray(0))
    mults = state.mults._replace(
        eta=jnp.asarray(rng.uniform(0, 5, (n, m)), jnp.float32),
        phi=jnp.asarray(rng.uniform(0, 2, (n, m)), jnp.float32),
        lam=jnp.asarray(rng.uniform(0, 2, (n, m)), jnp.float32))
    beta, gamma = training_weights(cfg, net, mults, use_lsa=True)
    beta, gamma = np.asarray(beta), np.asarray(gamma)
    eta = np.asarray(mults.eta)
    e = np.asarray(net.e)
    for i in range(n):
        for j in range(m):
            for k in range(m):
                expect = beta[i, k] + eta[i, j] - eta[i, k] - e[j, k]
                np.testing.assert_allclose(gamma[i, j, k], expect, rtol=1e-5,
                                           atol=1e-4)


def test_long_term_skew_constraint_approached():
    """With a feasible generation rate, DS's cumulative per-CU training
    fractions approach zeta_i / sum(zeta) within a few deltas (eq. 9 is a
    time-average constraint; exact satisfaction is asymptotic)."""
    cfg = CocktailConfig(n_cu=5, n_ec=3, delta=0.05, eps=0.15, pair_iters=20,
                         seed=11)
    st_, _ = run(cfg, DS, 120)
    omega = np.asarray(st_.queues.omega, np.float64)
    frac = omega.sum(axis=1) / max(omega.sum(), 1e-9)  # per-CU overall share
    target = cfg.proportions
    assert np.abs(frac - target).max() < 4 * cfg.delta


def test_lds_effective_multiplier_shift():
    """L-DS schedules with Theta~ = Theta + Theta' - pi: after warm-up the
    empirical multipliers are non-trivial (they learned the state)."""
    cfg = CocktailConfig(n_cu=6, n_ec=3, eps=0.05, pair_iters=15, seed=3)
    st_, _ = run(cfg, LDS, 30)
    emp = np.asarray(st_.emp_mults.mu)
    assert np.isfinite(emp).all()
    assert emp.sum() > 0  # learned something
