"""Cross-pod int8-compressed all-reduce: numerics + wire-bytes reduction,
on an 8-device fake mesh (subprocess so XLA flags apply before jax init)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, re
from jax.sharding import PartitionSpec as P, NamedSharding
sys_path_ok = True
from repro.parallel.collectives import cross_pod_sum_partials

mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
g_global = jnp.asarray(rng.normal(size=(2, 64)) * 3.0)  # per-pod partials

def run(x):
    def inner(xx):
        return cross_pod_sum_partials({"g": xx[0]}, mesh)["g"]
    return jax.shard_map(inner, mesh=mesh, in_specs=P("pod", None),
                         out_specs=P(None),
                         axis_names={"pod"}, check_vma=False)(x)

f = jax.jit(run, in_shardings=NamedSharding(mesh, P("pod", None)),
            out_shardings=NamedSharding(mesh, P(None)))
lowered = f.lower(jax.ShapeDtypeStruct((2, 64), jnp.float32))
compiled = lowered.compile()
out = f(g_global)
expect = np.asarray(g_global).sum(axis=0)
err = np.abs(np.asarray(out) - expect).max()
rel = err / np.abs(expect).max()
assert rel < 0.02, f"int8 roundtrip too lossy: {rel}"

hlo = compiled.as_text()
int8_colls = [l for l in hlo.splitlines() if re.search(r"s8\[[0-9,]*\][^=]*all-gather", l)]
f32_colls = [l for l in hlo.splitlines() if re.search(r"f32\[[0-9,]*\][^=]*all-(gather|reduce)", l)]
assert int8_colls, "expected int8 payload on the pod axis"
print("OK int8_collectives=", len(int8_colls), "rel_err=", rel)
"""


def test_compressed_allreduce_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       timeout=600)
    assert "OK int8_collectives=" in r.stdout, r.stdout + r.stderr
