"""Matching layer: greedy production paths vs the exact Thm.1/Thm.2 oracles,
plus brute-force validation of the oracles themselves on tiny instances."""
import itertools
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import matching, oracle


def _rand_logw(rng, n, m, lo=0.2, hi=4.0):
    # weights > 1 so logs are positive and objective ratios are meaningful
    return np.log(rng.uniform(np.e ** lo, np.e ** hi, size=(n, m)))


def brute_force_collection(logw):
    """Enumerate every CU->EC (or none) assignment; return best objective."""
    n, m = logw.shape
    best = 0.0
    for assign in itertools.product(range(m + 1), repeat=n):
        alpha = np.zeros((n, m))
        for i, a in enumerate(assign):
            if a > 0:
                alpha[i, a - 1] = 1.0
        best = max(best, oracle.collection_objective(logw, alpha))
    return best


class TestCollection:
    def test_oracle_matches_bruteforce(self):
        rng = np.random.default_rng(0)
        for _ in range(5):
            logw = _rand_logw(rng, 4, 2)
            alpha, theta = oracle.exact_collection(logw)
            obj = oracle.collection_objective(logw, np.asarray(alpha))
            assert obj == pytest.approx(brute_force_collection(logw), rel=1e-6)

    def test_greedy_feasible_and_half_approx(self):
        rng = np.random.default_rng(1)
        for trial in range(8):
            n, m = rng.integers(3, 9), rng.integers(2, 4)
            logw = _rand_logw(rng, int(n), int(m))
            alpha, theta = matching.greedy_collection(jnp.asarray(logw))
            alpha, theta = np.asarray(alpha), np.asarray(theta)
            # constraint (2): each CU at most one EC
            assert (alpha.sum(axis=1) <= 1 + 1e-6).all()
            # constraint (3): per-EC durations sum to <= 1
            assert (theta.sum(axis=0) <= 1 + 1e-6).all()
            # theta = 1/n_j on connections
            cnt = alpha.sum(axis=0)
            for j in range(int(m)):
                if cnt[j] > 0:
                    np.testing.assert_allclose(
                        theta[alpha[:, j] > 0, j], 1.0 / cnt[j], rtol=1e-5)
            g_obj = oracle.collection_objective(logw, alpha)
            e_alpha, _ = oracle.exact_collection(logw)
            e_obj = oracle.collection_objective(logw, np.asarray(e_alpha))
            assert e_obj >= g_obj - 1e-6
            if e_obj > 0:
                assert g_obj >= 0.5 * e_obj - 1e-6

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_greedy_never_negative_marginal(self, seed):
        """Greedy stops at non-positive marginal gain: removing any single CU
        from its EC never increases the objective."""
        rng = np.random.default_rng(seed)
        logw = np.log(rng.uniform(0.2, 40.0, size=(6, 3)))
        alpha = np.asarray(matching.greedy_collection(jnp.asarray(logw))[0])
        base = oracle.collection_objective(logw, alpha)
        for i in range(6):
            if alpha[i].sum() > 0:
                a2 = alpha.copy()
                a2[i] = 0
                assert oracle.collection_objective(logw, a2) <= base + 1e-6


def brute_force_pairing(solo, pair):
    """Best total value over all EC partitions into pairs + singletons,
    where singletons may also opt out (train nothing, value 0)."""
    m = len(solo)

    def rec(avail):
        if not avail:
            return 0.0
        j, rest = avail[0], avail[1:]
        best = rec(rest) + max(solo[j], 0.0)
        for k in rest:
            rem = tuple(u for u in rest if u != k)
            v = rec(rem) + pair[j, k]
            best = max(best, v)
        best = max(best, rec(rest))  # j opts out entirely
        return best

    return rec(tuple(range(m)))


class TestPairing:
    def test_oracle_matches_bruteforce(self):
        rng = np.random.default_rng(2)
        for _ in range(6):
            m = int(rng.integers(2, 6))
            solo = rng.normal(2.0, 2.0, size=m)
            pair = rng.normal(3.0, 3.0, size=(m, m))
            pair = (pair + pair.T) / 2
            np.fill_diagonal(pair, 0.0)
            match = np.asarray(oracle.exact_pairing(solo, pair))
            val = (np.diagonal(match) * solo).sum() + (np.triu(match, 1) * pair).sum()
            assert val == pytest.approx(brute_force_pairing(solo, pair), rel=1e-6, abs=1e-6)

    def test_greedy_feasible_and_half_approx(self):
        rng = np.random.default_rng(3)
        for _ in range(10):
            m = int(rng.integers(2, 8))
            solo = rng.uniform(0.0, 5.0, size=m)
            pair = rng.uniform(0.0, 10.0, size=(m, m))
            pair = (pair + pair.T) / 2
            match = np.asarray(matching.greedy_pairing(jnp.asarray(solo), jnp.asarray(pair)))
            # symmetric, each EC covered at most once
            np.testing.assert_allclose(match, match.T)
            assert (match.sum(axis=1) <= 1 + 1e-6).all()
            g_val = (np.diagonal(match) * solo).sum() + (np.triu(match, 1) * pair).sum()
            e_val = brute_force_pairing(solo, pair)
            assert g_val >= 0.5 * e_val - 1e-6


class TestAssignment:
    def test_greedy_disjoint_and_half(self):
        rng = np.random.default_rng(4)
        for _ in range(10):
            n, m = int(rng.integers(2, 10)), int(rng.integers(2, 5))
            w = rng.uniform(0.1, 10.0, size=(n, m))
            alpha = np.asarray(matching.greedy_assignment(jnp.asarray(w)))
            assert (alpha.sum(axis=1) <= 1 + 1e-6).all()
            assert (alpha.sum(axis=0) <= 1 + 1e-6).all()
            e_alpha = np.asarray(oracle.exact_assignment(w))
            assert (alpha * w).sum() >= 0.5 * (e_alpha * w).sum() - 1e-6
