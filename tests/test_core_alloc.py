"""Training-allocation solvers: feasibility + optimality properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import training_alloc as ta

TOL = 1e-3


def _feasible_solo(x, r, budget):
    x = np.asarray(x)
    assert (x >= -1e-6).all()
    assert (x <= np.asarray(r) + 1e-4).all()
    assert x.sum() <= budget * (1 + 1e-4) + 1e-4


class TestSoloWaterfill:
    @given(st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_feasible_and_waterlevel_structure(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 12))
        beta = rng.uniform(-1.0, 5.0, n)
        r = rng.uniform(0.0, 50.0, n)
        budget = float(rng.uniform(0.0, 120.0))
        x, val = ta.solo_waterfill(jnp.asarray(beta, jnp.float32),
                                   jnp.asarray(r, jnp.float32),
                                   jnp.asarray(budget, jnp.float32))
        x = np.asarray(x)
        _feasible_solo(x, r, budget)
        # inactive sources get nothing
        assert (x[(beta <= 0) | (r <= 1e-9)] == 0).all()
        active = (beta > 0) & (r > 1e-9) & (x > 1e-6)
        if active.sum() >= 2:
            # water-level structure: every active x is either at its cap or at
            # the common level
            free = active & (x < r - 1e-4)
            if free.sum() >= 2:
                lv = x[free]
                assert np.ptp(lv) <= 1e-2 * max(lv.max(), 1.0)

    def test_beats_random_feasible(self):
        rng = np.random.default_rng(7)
        for _ in range(20):
            n = int(rng.integers(2, 8))
            beta = rng.uniform(0.1, 5.0, n)
            r = rng.uniform(1.0, 30.0, n)
            budget = float(rng.uniform(5.0, 60.0))
            x, val = ta.solo_waterfill(jnp.asarray(beta, jnp.float32),
                                       jnp.asarray(r, jnp.float32),
                                       jnp.asarray(budget, jnp.float32))
            val = float(val)
            for _ in range(30):
                # random feasible interior point allocating to all sources
                u = rng.uniform(0.2, 1.0, n)
                cand = np.minimum(r, u * budget / u.sum())
                if cand.sum() > budget:
                    cand *= budget / cand.sum()
                cand = np.maximum(cand, 1e-6)
                v = np.sum(np.log(beta * np.minimum(cand, r)))
                assert val >= v - TOL * max(1.0, abs(v))

    def test_exhausts_budget_when_binding(self):
        beta = jnp.asarray([1.0, 2.0, 3.0])
        r = jnp.asarray([10.0, 10.0, 10.0])
        x, _ = ta.solo_waterfill(beta, r, jnp.asarray(6.0))
        assert float(jnp.sum(x)) == pytest.approx(6.0, rel=1e-4)
        np.testing.assert_allclose(np.asarray(x), [2.0, 2.0, 2.0], rtol=1e-4)

    def test_caps_respected_when_slack(self):
        beta = jnp.asarray([1.0, 1.0])
        r = jnp.asarray([3.0, 5.0])
        x, _ = ta.solo_waterfill(beta, r, jnp.asarray(100.0))
        np.testing.assert_allclose(np.asarray(x), [3.0, 5.0], rtol=1e-5)


def _pair_instance(rng, n):
    return dict(
        b_j=rng.uniform(0.1, 4.0, n), g_kj=rng.uniform(0.05, 4.0, n),
        b_k=rng.uniform(0.1, 4.0, n), g_jk=rng.uniform(0.05, 4.0, n),
        r_j=rng.uniform(0.5, 30.0, n), r_k=rng.uniform(0.5, 30.0, n),
        budget_j=float(rng.uniform(5.0, 80.0)),
        budget_k=float(rng.uniform(5.0, 80.0)),
        link=float(rng.uniform(1.0, 40.0)),
    )


def _check_pair_feasible(pa, inst):
    x_j, x_k = np.asarray(pa.x_j), np.asarray(pa.x_k)
    y_jk, y_kj = np.asarray(pa.y_jk), np.asarray(pa.y_kj)
    for v in (x_j, x_k, y_jk, y_kj):
        assert (v >= -1e-6).all()
    assert (x_j + y_jk <= inst["r_j"] * (1 + 1e-4) + 1e-4).all()  # (13) queue j
    assert (x_k + y_kj <= inst["r_k"] * (1 + 1e-4) + 1e-4).all()  # (13) queue k
    assert (x_j + y_kj).sum() <= inst["budget_j"] * (1 + 1e-4) + 1e-3  # (8) at j
    assert (x_k + y_jk).sum() <= inst["budget_k"] * (1 + 1e-4) + 1e-3  # (8) at k
    assert (y_jk + y_kj).sum() <= inst["link"] * (1 + 1e-4) + 1e-3  # (6)


class TestPairAllocate:
    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_feasible(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 10))
        inst = _pair_instance(rng, n)
        pa = ta.pair_allocate(**{k: jnp.asarray(v, jnp.float32) for k, v in inst.items()})
        _check_pair_feasible(pa, inst)

    def test_at_least_solo_value(self):
        """Pairing with borrowing must not be worse than independent solo
        training (y=0 is feasible for problem (21))."""
        rng = np.random.default_rng(11)
        worse = 0
        for _ in range(15):
            n = int(rng.integers(2, 8))
            inst = _pair_instance(rng, n)
            j = {k: jnp.asarray(v, jnp.float32) for k, v in inst.items()}
            pa = ta.pair_allocate(**j, iters=120, sweeps=6)
            _, v_j = ta.solo_waterfill(j["b_j"], j["r_j"], j["budget_j"])
            _, v_k = ta.solo_waterfill(j["b_k"], j["r_k"], j["budget_k"])
            if float(pa.value) < float(v_j + v_k) - 0.05 * abs(float(v_j + v_k)) - 0.1:
                worse += 1
        assert worse <= 2  # fixed-iteration solver: allow rare small shortfalls

    def test_close_to_longrun_oracle(self):
        rng = np.random.default_rng(13)
        for _ in range(5):
            n = int(rng.integers(2, 6))
            inst = {k: jnp.asarray(v, jnp.float32) for k, v in _pair_instance(rng, n).items()}
            fast = ta.pair_allocate(**inst, iters=60, sweeps=4)
            slow = ta.pair_allocate(**inst, iters=1500, sweeps=10)
            assert float(fast.value) >= float(slow.value) - 0.1 * abs(float(slow.value)) - 0.5


class TestLinear:
    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_linear_solo_exact_fractional_knapsack(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 10))
        beta = rng.uniform(-1.0, 5.0, n)
        r = rng.uniform(0.0, 20.0, n)
        budget = float(rng.uniform(0.0, 60.0))
        x, val = ta.linear_solo(jnp.asarray(beta, jnp.float32),
                                jnp.asarray(r, jnp.float32),
                                jnp.asarray(budget, jnp.float32))
        _feasible_solo(np.asarray(x), r, budget)
        # LP optimum check: value of greedy == LP optimum for 1 resource + caps
        order = np.argsort(-beta)
        rem, ref = budget, 0.0
        for i in order:
            if beta[i] <= 0 or rem <= 0:
                continue
            amt = min(r[i], rem)
            ref += beta[i] * amt
            rem -= amt
        assert float(val) == pytest.approx(ref, rel=1e-4, abs=1e-3)

    def test_linear_pair_feasible(self):
        rng = np.random.default_rng(17)
        for _ in range(10):
            n = int(rng.integers(1, 8))
            inst = _pair_instance(rng, n)
            pa = ta.linear_pair(**{k: jnp.asarray(v, jnp.float32) for k, v in inst.items()})
            _check_pair_feasible(pa, inst)


class TestFullAllocate:
    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_feasible(self, seed):
        rng = np.random.default_rng(seed)
        n, m = int(rng.integers(2, 6)), int(rng.integers(2, 5))
        beta = rng.uniform(-0.5, 3.0, (n, m))
        gamma = rng.uniform(-0.5, 3.0, (n, m, m))
        r = rng.uniform(0.5, 20.0, (n, m))
        budgets = rng.uniform(5.0, 50.0, m)
        links = rng.uniform(1.0, 30.0, (m, m))
        links = (links + links.T) / 2
        np.fill_diagonal(links, 0.0)
        x, y, val = ta.full_allocate(
            jnp.asarray(beta, jnp.float32), jnp.asarray(gamma, jnp.float32),
            jnp.asarray(r, jnp.float32), jnp.asarray(budgets, jnp.float32),
            jnp.asarray(links, jnp.float32))
        x, y = np.asarray(x), np.asarray(y)
        assert (x >= -1e-6).all() and (y >= -1e-6).all()
        assert (y[:, np.arange(m), np.arange(m)] <= 1e-6).all()  # no self-offload
        dep = x + y.sum(axis=2)
        assert (dep <= r * (1 + 1e-3) + 1e-3).all()  # (13)
        trained = x.sum(axis=0) + y.sum(axis=(0, 1))
        assert (trained <= budgets * (1 + 1e-3) + 1e-2).all()  # (8)
        flow = y.sum(axis=0)
        assert ((flow + flow.T) <= links * (1 + 1e-3) + 1e-2 + np.eye(m) * 1e9).all()  # (6)
