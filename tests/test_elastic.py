"""Elastic scaling: a checkpoint written while training on a 4-device mesh
restores and continues on an 8-device mesh (different DP width), preserving
the learning state. Stages run in subprocesses so each gets its own fake
device count."""
import pathlib
import subprocess
import sys

STAGE = r"""
import os, sys
n_dev, ckpt_dir, steps = sys.argv[1], sys.argv[2], int(sys.argv[3])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
import json
from repro.launch import train
summary = train.main([
    "--arch", "whisper-base", "--reduced", "--steps", str(steps),
    "--batch", "8", "--seq", "32", "--checkpoint-dir", ckpt_dir,
    "--checkpoint-every", "5", "--lr", "1e-3", "--log-every", "100",
])
print("SUMMARY:" + json.dumps(summary))
"""


def _stage(n_dev, ckpt, steps):
    r = subprocess.run([sys.executable, "-c", STAGE, str(n_dev), str(ckpt), str(steps)],
                       capture_output=True, text=True, timeout=1200,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return r.stdout


def test_scale_up_mid_training(tmp_path):
    ckpt = tmp_path / "ck"
    _stage(4, ckpt, 10)  # train on 4 devices, snapshot at step 10
    out = _stage(8, ckpt, 20)  # resume the same run on 8 devices
    assert "resumed from step 10" in out
    from repro.checkpoint import latest_step
    assert latest_step(ckpt) == 20
