"""Golden-trace regression: a committed fixed-seed run() trace for the paper
testbed config. Kernel/solver refactors that change the schedule's numerics
(beyond float reassociation noise) fail loudly here instead of silently
drifting the reproduction.

Regenerate (after an INTENTIONAL numerics change, with the diff reviewed):

    PYTHONPATH=src python tests/test_golden_trace.py --regen
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import DS, LDS, CocktailConfig, run

GOLDEN = pathlib.Path(__file__).parent / "golden" / "testbed_trace.json"
SLOTS = 16

# Paper Sec. IV-A testbed scale (see benchmarks/common.testbed_config; inlined
# so the test suite does not depend on the benchmarks package).
CFG = CocktailConfig(n_cu=6, n_ec=3, delta=0.02, eps=0.1, q0=5000.0,
                     zeta=500.0, d_base=2000.0, cap_d_base=8000.0,
                     f_base=(8000.0, 20000.0, 8000.0),
                     c_base=50.0, e_base=50.0, p_base=200.0,
                     pair_iters=30, seed=0)


def _trace(spec):
    state, recs = run(CFG, spec, SLOTS)
    return {
        "cost": np.asarray(recs.cost, np.float64).tolist(),
        "trained": np.asarray(recs.trained, np.float64).tolist(),
        "q_backlog": np.asarray(recs.q_backlog, np.float64).tolist(),
        "r_backlog": np.asarray(recs.r_backlog, np.float64).tolist(),
        "skew": np.asarray(recs.skew, np.float64).tolist(),
        "total_cost": float(state.total_cost),
        "total_trained": float(state.total_trained),
        "final_q": np.asarray(state.queues.q, np.float64).tolist(),
    }


def _traces():
    return {spec.name: _trace(spec) for spec in (DS, LDS)}


def _assert_matches_golden(spec, current):
    assert GOLDEN.exists(), "golden trace missing; run with --regen (see docstring)"
    golden = json.loads(GOLDEN.read_text())[spec.name]
    for key, want in golden.items():
        got = current[key]
        # tight but not bit-exact: float32 reassociation across backends/XLA
        # versions; real solver drift is orders of magnitude larger
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3, err_msg=key)


@pytest.mark.parametrize("spec", [DS, LDS], ids=lambda s: s.name)
def test_trace_matches_golden(spec):
    _assert_matches_golden(spec, _trace(spec))


@pytest.mark.parametrize("spec", [DS, LDS], ids=lambda s: s.name)
def test_switched_dispatch_matches_golden(spec):
    """The branch-free (lax.switch) dispatch path reproduces the committed
    golden trace too — the policy tables cannot drift from the static path."""
    from repro.core import SWITCHED, init_state, with_policy

    params = with_policy(CFG.params, spec)
    state, recs = run(CFG.shape, SWITCHED, SLOTS,
                      state=init_state(CFG.shape, params, seed=CFG.seed),
                      params=params)
    current = {
        "cost": np.asarray(recs.cost, np.float64).tolist(),
        "trained": np.asarray(recs.trained, np.float64).tolist(),
        "q_backlog": np.asarray(recs.q_backlog, np.float64).tolist(),
        "r_backlog": np.asarray(recs.r_backlog, np.float64).tolist(),
        "skew": np.asarray(recs.skew, np.float64).tolist(),
        "total_cost": float(state.total_cost),
        "total_trained": float(state.total_trained),
        "final_q": np.asarray(state.queues.q, np.float64).tolist(),
    }
    _assert_matches_golden(spec, current)


if __name__ == "__main__":
    import sys

    if "--regen" not in sys.argv:
        sys.exit("refusing to overwrite the golden trace without --regen")
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(_traces(), indent=1) + "\n")
    print(f"wrote {GOLDEN}")
