"""Masked-equivalence harness for ragged (mixed-shape) fleets.

The padding contract: a slice whose true shape is (N, M), zero-padded to a
larger compiled ``ShapeConfig`` with ``cu_mask``/``ec_mask`` set, must
reproduce its standalone unpadded ``run()`` trace — per-slot records, final
queues/multipliers and accumulated objective — because

  * network sampling is entity-keyed (value at (i, j) never depends on the
    array shape) and masked entities get zero capacity/arrivals,
  * masked entities carry -inf solver weights, so collection, pairing and
    training allocate exactly zero to them,
  * record scalars are sums to which padded entries contribute exact zeros.

The single-slice padded path is asserted BIT-exact on CPU; the vmapped fleet
path reuses the tolerances of tests/test_fleet.py (vmap may re-associate
reductions).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (CU_FULL, DS, EC_FULL, EC_SELF, LDS, NO_LSA, NO_SDC,
                        NO_SLT, CocktailConfig, FleetEngine, ShapeConfig,
                        SliceParams, init_state, ragged_pad_shape, run,
                        trim_state)
from repro.core import metrics
from repro.core.fleet import slice_records, unstack

BASE = CocktailConfig(n_cu=8, n_ec=3, eps=0.1, pair_iters=15, seed=7,
                      f_base=(8000.0, 20000.0, 12000.0))
SLOTS = 10


def _padded_run(cfg: CocktailConfig, pad: ShapeConfig, spec, n_slots: int):
    params = SliceParams.from_config(cfg, pad_shape=pad)
    state = init_state(pad, params, seed=cfg.seed)
    return run(pad, spec, n_slots, state=state, params=params)


def _assert_records_equal(recs_pad, recs_ref, exact=True):
    for field in recs_ref._fields:
        a = np.asarray(getattr(recs_ref, field))
        b = np.asarray(getattr(recs_pad, field))
        if exact:
            np.testing.assert_array_equal(b, a, err_msg=field)
        else:
            np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-4,
                                       err_msg=field)


def _assert_trimmed_state_equal(st_pad, st_ref, shape, exact=True):
    tr = trim_state(st_pad, shape)
    assert_eq = (np.testing.assert_array_equal if exact else
                 lambda b, a, err_msg: np.testing.assert_allclose(
                     b, a, rtol=1e-4, atol=1e-2, err_msg=err_msg))
    for name in ("q", "r", "omega"):
        assert_eq(np.asarray(getattr(tr.queues, name)),
                  np.asarray(getattr(st_ref.queues, name)), err_msg=name)
    for name in ("mu", "eta", "phi", "lam"):
        assert_eq(np.asarray(getattr(tr.mults, name)),
                  np.asarray(getattr(st_ref.mults, name)), err_msg=name)
    assert_eq(np.asarray(tr.uploaded), np.asarray(st_ref.uploaded),
              err_msg="uploaded")
    if exact:
        assert float(tr.total_cost) == float(st_ref.total_cost)
        assert float(tr.total_trained) == float(st_ref.total_trained)
    else:
        np.testing.assert_allclose(float(tr.total_cost),
                                   float(st_ref.total_cost), rtol=1e-4)


def _assert_padding_zero(st_pad, shape):
    n, m = shape.n_cu, shape.n_ec
    assert (np.asarray(st_pad.queues.q)[n:] == 0).all()
    assert (np.asarray(st_pad.queues.r)[n:, :] == 0).all()
    assert (np.asarray(st_pad.queues.r)[:, m:] == 0).all()
    assert (np.asarray(st_pad.queues.omega)[n:, :] == 0).all()
    assert (np.asarray(st_pad.queues.omega)[:, m:] == 0).all()
    assert (np.asarray(st_pad.mults.mu)[n:] == 0).all()
    for name in ("eta", "phi", "lam"):
        v = np.asarray(getattr(st_pad.mults, name))
        assert (v[n:, :] == 0).all() and (v[:, m:] == 0).all()
    assert (np.asarray(st_pad.uploaded)[n:] == 0).all()


@pytest.mark.parametrize("pad", [(8, 4), (12, 3), (12, 5), (16, 8)],
                         ids=lambda p: f"pad{p[0]}x{p[1]}")
def test_padded_matches_unpadded_bitexact(pad):
    """DS at several pad shapes: padded run == unpadded run, bit for bit."""
    pad_shape = ShapeConfig(n_cu=pad[0], n_ec=pad[1], pair_iters=BASE.pair_iters)
    st_ref, recs_ref = run(BASE, DS, SLOTS)
    st_pad, recs_pad = _padded_run(BASE, pad_shape, DS, SLOTS)
    _assert_records_equal(recs_pad, recs_ref, exact=True)
    _assert_trimmed_state_equal(st_pad, st_ref, BASE.shape, exact=True)
    _assert_padding_zero(st_pad, BASE.shape)


@pytest.mark.parametrize("spec", [LDS, NO_SDC, NO_SLT, NO_LSA, EC_FULL,
                                  EC_SELF, CU_FULL], ids=lambda s: s.name)
def test_padded_matches_unpadded_all_policies(spec):
    """Every jittable policy variant honours the masks (collection, linear
    and log-utility training, full-allocation, learning-aid virtual path)."""
    pad_shape = ShapeConfig(n_cu=12, n_ec=5, pair_iters=BASE.pair_iters)
    st_ref, recs_ref = run(BASE, spec, SLOTS)
    st_pad, recs_pad = _padded_run(BASE, pad_shape, spec, SLOTS)
    _assert_records_equal(recs_pad, recs_ref, exact=True)
    _assert_trimmed_state_equal(st_pad, st_ref, BASE.shape, exact=True)
    _assert_padding_zero(st_pad, BASE.shape)


def test_masked_decision_entries_zero():
    """One slot at pad shape: the Decision itself allocates exactly nothing
    to padded entities (alpha/theta/x rows+cols, y and z slabs)."""
    from repro.core import step

    pad_shape = ShapeConfig(n_cu=12, n_ec=5, pair_iters=BASE.pair_iters)
    params = SliceParams.from_config(BASE, pad_shape=pad_shape)
    state = init_state(pad_shape, params, seed=BASE.seed)
    n, m = BASE.n_cu, BASE.n_ec
    for _ in range(3):
        state, _, dec = step(pad_shape, DS, state, params=params)
        for name in ("alpha", "theta", "x"):
            v = np.asarray(getattr(dec, name))
            assert (v[n:, :] == 0).all() and (v[:, m:] == 0).all(), name
        y = np.asarray(dec.y)
        assert (y[n:] == 0).all() and (y[:, m:, :] == 0).all() and (y[:, :, m:] == 0).all()
        z = np.asarray(dec.z)
        assert (z[m:, :] == 0).all() and (z[:, m:] == 0).all()


def test_ragged_fleet_matches_standalone_runs():
    """Acceptance: distinct-(N, M) slices in ONE jitted program, each slice's
    per-slot records matching its standalone unpadded run()."""
    cfgs = [
        CocktailConfig(n_cu=6, n_ec=3, pair_iters=15, seed=0,
                       f_base=(8000.0, 20000.0, 12000.0)),
        CocktailConfig(n_cu=12, n_ec=4, pair_iters=15, seed=1, zeta=800.0),
        CocktailConfig(n_cu=9, n_ec=2, pair_iters=15, seed=2, eps=0.2),
        dataclasses.replace(BASE, seed=3),
    ]
    eng = FleetEngine.from_ragged_configs(cfgs, DS)
    assert eng.shape == ShapeConfig(n_cu=12, n_ec=4, pair_iters=15)
    assert eng.n_slices == 4
    st, recs = eng.run(SLOTS)
    assert recs.cost.shape == (SLOTS, 4)
    for k, cfg in enumerate(cfgs):
        st_ref, recs_ref = run(cfg, DS, SLOTS)
        # vmap may re-associate reductions: same tolerance as test_fleet.py
        _assert_records_equal(slice_records(recs, k), recs_ref, exact=False)
        _assert_trimmed_state_equal(unstack(st, k), st_ref, cfg.shape,
                                    exact=False)
        _assert_padding_zero(unstack(st, k), cfg.shape)
        # slice_state trims, so shape-aware metrics work off the original cfg
        s = metrics.summary(cfg, eng.slice_state(st, k))
        np.testing.assert_allclose(s["total_trained"],
                                   float(st_ref.total_trained), rtol=1e-4)


def test_ragged_fleet_lds():
    """Learning-aid DS (virtual plain-P1/P2 decisions) also masks cleanly in
    a ragged fleet."""
    cfgs = [CocktailConfig(n_cu=5, n_ec=2, pair_iters=12, seed=4),
            CocktailConfig(n_cu=10, n_ec=3, pair_iters=12, seed=5)]
    eng = FleetEngine.from_ragged_configs(cfgs, LDS)
    st, recs = eng.run(8)
    for k, cfg in enumerate(cfgs):
        st_ref, recs_ref = run(cfg, LDS, 8)
        _assert_records_equal(slice_records(recs, k), recs_ref, exact=False)
        _assert_padding_zero(unstack(st, k), cfg.shape)


def test_ragged_rejects_mismatched_pair_iters():
    a = CocktailConfig(n_cu=4, n_ec=2, pair_iters=10)
    b = CocktailConfig(n_cu=6, n_ec=3, pair_iters=20)
    with pytest.raises(ValueError):
        FleetEngine.from_ragged_configs([a, b], DS)
    with pytest.raises(ValueError):
        FleetEngine.from_ragged_configs([], DS)


def test_ragged_pad_shape_and_mask_layout():
    shapes = [ShapeConfig(4, 2, 10), ShapeConfig(6, 3, 10), ShapeConfig(5, 5, 10)]
    assert ragged_pad_shape(shapes) == ShapeConfig(6, 5, 10)
    cfg = CocktailConfig(n_cu=4, n_ec=2, pair_iters=10)
    p = SliceParams.from_config(cfg, pad_shape=ShapeConfig(6, 5, 10))
    np.testing.assert_array_equal(np.asarray(p.cu_mask), [1, 1, 1, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(p.ec_mask), [1, 1, 0, 0, 0])
    assert (np.asarray(p.zeta)[4:] == 0).all()
    assert (np.asarray(p.f_base)[2:] == 0).all()
    np.testing.assert_allclose(np.asarray(p.proportions).sum(), 1.0, rtol=1e-6)
    with pytest.raises(ValueError):
        SliceParams.from_config(cfg, pad_shape=ShapeConfig(3, 2, 10))


@pytest.mark.tier2
def test_padded_equivalence_property():
    """Hypothesis sweep over random true shapes, pad shapes and seeds: the
    padded DS trace is bit-exact against the unpadded one."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as hst

    @given(hst.integers(2, 10), hst.integers(2, 4), hst.integers(0, 6),
           hst.integers(0, 3), hst.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def check(n_cu, n_ec, pad_n, pad_m, seed):
        cfg = CocktailConfig(n_cu=n_cu, n_ec=n_ec, eps=0.12, pair_iters=10,
                             seed=seed % 89)
        pad_shape = ShapeConfig(n_cu=n_cu + pad_n, n_ec=n_ec + pad_m,
                               pair_iters=10)
        st_ref, recs_ref = run(cfg, DS, 6)
        st_pad, recs_pad = _padded_run(cfg, pad_shape, DS, 6)
        _assert_records_equal(recs_pad, recs_ref, exact=True)
        _assert_trimmed_state_equal(st_pad, st_ref, cfg.shape, exact=True)
        _assert_padding_zero(st_pad, cfg.shape)

    check()
