"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + the chunked jnp production paths vs the exact references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import AttnSpec, attention_ref
from repro.kernels.mamba_scan import ops as ms_ops
from repro.kernels.mamba_scan.kernel import mamba1_scan_pallas
from repro.kernels.mamba_scan.ref import mamba1_scan_ref, mamba2_scan_ref
from repro.kernels.matching.kernel import greedy_assignment_pallas
from repro.kernels.matching.ref import greedy_assignment_ref

RNG = np.random.default_rng(42)


def _qkv(b, sq, skv, h, hkv, hd, dtype):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, skv, hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, skv, hkv, hd)), dtype)
    qp = jnp.broadcast_to(jnp.arange(skv - sq, skv, dtype=jnp.int32), (b, sq))
    kp = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (b, skv))
    return q, k, v, qp, kp


ATTN_CASES = [
    (2, 128, 128, 4, 2, 64, AttnSpec(causal=True)),
    (1, 256, 256, 8, 8, 32, AttnSpec(causal=True, window=64)),
    (2, 128, 128, 4, 1, 64, AttnSpec(causal=True, softcap=30.0)),
    (1, 64, 192, 4, 2, 32, AttnSpec(causal=False)),
    (1, 128, 128, 2, 2, 16, AttnSpec(causal=True, prefix_len=32)),
]


class TestFlashAttention:
    @pytest.mark.parametrize("case", ATTN_CASES, ids=str)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_pallas_matches_ref(self, case, dtype):
        b, sq, skv, h, hkv, hd, spec = case
        q, k, v, qp, kp = _qkv(b, sq, skv, h, hkv, hd, dtype)
        ref = attention_ref(q, k, v, qp, kp, spec)
        out = flash_attention_pallas(q, k, v, qp, kp, spec, interpret=True,
                                     block_q=64, block_kv=64)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(ref, np.float32),
                                   np.asarray(out, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("case", ATTN_CASES, ids=str)
    def test_chunked_matches_ref(self, case):
        b, sq, skv, h, hkv, hd, spec = case
        q, k, v, qp, kp = _qkv(b, sq, skv, h, hkv, hd, jnp.float32)
        ref = attention_ref(q, k, v, qp, kp, spec)
        out = fa_ops.attention_chunked(q, k, v, qp, kp, spec,
                                       q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-5, atol=2e-5)

    def test_chunked_with_kv_valid(self):
        b, sq, skv, h, hkv, hd = 2, 1, 128, 4, 2, 32
        q, k, v, qp, kp = _qkv(b, sq, skv, h, hkv, hd, jnp.float32)
        valid = jnp.asarray(RNG.random((b, skv)) > 0.3)
        spec = AttnSpec(causal=True)
        ref = attention_ref(q, k, v, qp, kp, spec, kv_valid=valid)
        out = fa_ops.attention_chunked(q, k, v, qp, kp, spec, kv_valid=valid,
                                       q_chunk=1, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-5, atol=2e-5)

    def test_decode_grouped_path(self):
        b, skv, h, hkv, hd = 2, 96, 8, 2, 32
        q, k, v, qp, kp = _qkv(b, 1, skv, h, hkv, hd, jnp.float32)
        spec = AttnSpec(causal=True)
        ref = attention_ref(q, k, v, qp, kp, spec)
        out = fa_ops.flash_attention(q, k, v, qp, kp, spec, impl="chunked")
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-5, atol=2e-5)

    def test_pallas_grad_matches_ref(self):
        b, s, h, hkv, hd = 1, 64, 4, 2, 32
        q, k, v, qp, kp = _qkv(b, s, s, h, hkv, hd, jnp.float32)
        spec = AttnSpec(causal=True)

        def loss_p(q, k, v):
            return jnp.sum(flash_attention_pallas(q, k, v, qp, kp, spec,
                                                  interpret=True,
                                                  block_q=32, block_kv=32) ** 2)

        def loss_r(q, k, v):
            return jnp.sum(attention_ref(q, k, v, qp, kp, spec) ** 2)

        gp = jax.grad(loss_p, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
        for a, bb in zip(gp, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                       rtol=1e-3, atol=1e-3)


def _mamba1_inputs(b, s, di, n, dtype=jnp.float32):
    x = jnp.asarray(RNG.normal(size=(b, s, di)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(b, s, di)), dtype)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(di, n)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), dtype)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), dtype)
    return x, dt, a, bm, cm


class TestMambaScan:
    @pytest.mark.parametrize("shape", [(1, 64, 32, 8), (2, 128, 64, 16),
                                       (1, 96, 48, 4)])
    def test_chunked_matches_ref_m1(self, shape):
        b, s, di, n = shape
        x, dt, a, bm, cm = _mamba1_inputs(b, s, di, n)
        y_ref, h_ref = mamba1_scan_ref(x, dt, a, bm, cm)
        y, h = ms_ops.mamba1_scan_chunked(x, dt, a, bm, cm, chunk=32)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("shape", [(1, 64, 32, 8), (2, 128, 64, 16)])
    def test_pallas_matches_ref_m1(self, shape):
        b, s, di, n = shape
        x, dt, a, bm, cm = _mamba1_inputs(b, s, di, n)
        y_ref, h_ref = mamba1_scan_ref(x, dt, a, bm, cm)
        y, h = mamba1_scan_pallas(x, dt, a, bm, cm, chunk=32, block_d=16,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h), rtol=2e-4, atol=2e-4)

    def test_pallas_with_initial_state(self):
        b, s, di, n = 1, 32, 16, 8
        x, dt, a, bm, cm = _mamba1_inputs(b, s, di, n)
        h0 = jnp.asarray(RNG.normal(size=(b, di, n)), jnp.float32)
        y_ref, h_ref = mamba1_scan_ref(x, dt, a, bm, cm, h0=h0)
        y, h = mamba1_scan_pallas(x, dt, a, bm, cm, h0=h0, chunk=16,
                                  block_d=16, interpret=True)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("shape", [(1, 64, 4, 16, 8), (2, 128, 8, 32, 16)])
    def test_chunked_matches_ref_m2(self, shape):
        b, s, h, p, n = shape
        x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(b, s, h)), jnp.float32)
        a = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
        bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
        cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
        y_ref, h_ref = mamba2_scan_ref(x, dt, a, bm, cm)
        y, hh = ms_ops.mamba2_scan_chunked(x, dt, a, bm, cm, chunk=32)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y), rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(h_ref), np.asarray(hh), rtol=3e-4, atol=3e-4)


class TestMatchingKernel:
    @pytest.mark.parametrize("shape", [(16, 4), (64, 8), (256, 16)])
    def test_pallas_matches_ref(self, shape):
        n, m = shape
        w = jnp.asarray(RNG.uniform(-1.0, 10.0, size=(n, m)), jnp.float32)
        ref = greedy_assignment_ref(w)
        out = greedy_assignment_pallas(w, interpret=True)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out))

    def test_all_negative_selects_nothing(self):
        w = -jnp.ones((32, 4))
        out = greedy_assignment_pallas(w, interpret=True)
        assert float(jnp.sum(out)) == 0.0
