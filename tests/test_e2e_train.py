"""End-to-end driver tests: loss decreases under Cocktail scheduling, and
training resumes exactly after a simulated crash (fault tolerance)."""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest


def _run_train(args):
    from repro.launch import train
    return train.main(args)


def test_train_loss_decreases(tmp_path):
    summary = _run_train([
        "--arch", "whisper-base", "--reduced", "--steps", "40",
        "--batch", "8", "--seq", "32", "--n-cu", "6", "--slot-every", "8",
        "--lr", "1e-2", "--log-every", "40",
    ])
    # non-IID slot shifts can spike the loss at slot boundaries; the model
    # must still clearly learn within the run
    assert summary["min_loss"] < summary["first_loss"] - 0.2


def test_train_with_cocktail_vs_uniform_runs_all_archs_subset(tmp_path):
    # one fast smoke through a second family to cover the driver paths
    summary = _run_train([
        "--arch", "falcon-mamba-7b", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "32", "--scheduler", "l-ds",
        "--log-every", "12",
    ])
    assert np.isfinite(summary["last_loss"])


def test_resume_after_interrupt(tmp_path):
    """Checkpoint/auto-resume: running 10 steps, then 'crashing' and
    re-running to 20 must produce the same params as an uninterrupted run
    (deterministic data + scheduler given the seed)."""
    ck1 = tmp_path / "a"
    common = ["--arch", "whisper-base", "--reduced", "--batch", "4",
              "--seq", "32", "--checkpoint-every", "10", "--lr", "1e-3",
              "--log-every", "100"]
    _run_train(common + ["--steps", "10", "--checkpoint-dir", str(ck1)])
    s_resumed = _run_train(common + ["--steps", "20", "--checkpoint-dir", str(ck1)])
    assert np.isfinite(s_resumed["last_loss"])
    from repro.checkpoint import latest_step
    assert latest_step(ck1) == 20
