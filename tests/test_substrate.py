"""Substrate layers: checkpointing (fault tolerance + elasticity), optimizer,
gradient compression, data sources/sampler, sharding rules."""
import dataclasses
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro.core import CocktailConfig, Decision, DS, init_state, step
from repro.data import CocktailSampler, TokenSource, TrafficSource
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, int8_compress, int8_decompress)
from repro.optim.compression import topk_roundtrip_with_feedback


class TestCheckpoint:
    def _tree(self, key):
        k1, k2 = jax.random.split(key)
        return {"a": jax.random.normal(k1, (4, 8)),
                "nested": {"b": jax.random.normal(k2, (3,)),
                           "c": jnp.arange(5, dtype=jnp.int32)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree(jax.random.PRNGKey(0))
        ckpt.save(tmp_path, 7, tree, extra={"note": "hi"})
        out, meta = ckpt.restore(tmp_path, 7, tree)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
                     tree, out)
        assert meta == {"note": "hi"}

    def test_latest_and_retention(self, tmp_path):
        mgr = ckpt.CheckpointManager(str(tmp_path), every_steps=1, keep=2)
        tree = self._tree(jax.random.PRNGKey(1))
        for s in (1, 2, 3, 4):
            mgr.maybe_save(s, tree)
        assert ckpt.latest_step(tmp_path) == 4
        files = sorted(p.name for p in tmp_path.iterdir())
        assert files == ["step_0000000003.npz", "step_0000000004.npz"]

    def test_interrupted_write_keeps_previous(self, tmp_path):
        """A crash mid-write must never corrupt the newest snapshot: tmp file
        left behind, latest still loads."""
        tree = self._tree(jax.random.PRNGKey(2))
        ckpt.save(tmp_path, 1, tree)
        (tmp_path / "garbage.tmp").write_bytes(b"\x00" * 100)  # simulated crash
        assert ckpt.latest_step(tmp_path) == 1
        out, _ = ckpt.restore(tmp_path, 1, tree)
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))

    def test_elastic_restore_new_mesh(self, tmp_path):
        """Snapshot written under one topology restores under another."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        ckpt.save(tmp_path, 1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P(None, None))}
        out, _ = ckpt.restore_sharded(tmp_path, 1, tree, sh)
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))

    def test_resume_roundtrip_matches(self, tmp_path):
        mgr = ckpt.CheckpointManager(str(tmp_path), every_steps=1)
        tree = self._tree(jax.random.PRNGKey(3))
        mgr.maybe_save(5, tree, extra={"step": 5})
        res = mgr.resume(tree)
        assert res is not None
        out, meta, s = res
        assert s == 5 and meta["step"] == 5


class TestOptim:
    def test_adamw_decreases_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(grads, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clip_norm(self):
        cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        _, _, m = adamw_update({"w": jnp.asarray([100.0, 0, 0])}, state, params, cfg)
        assert m["grad_norm"] == pytest.approx(100.0, rel=1e-4)

    def test_cosine_schedule_shape(self):
        s0 = cosine_schedule(jnp.asarray(0), 1000, warmup_steps=100)
        s_mid = cosine_schedule(jnp.asarray(550), 1000, warmup_steps=100)
        s_end = cosine_schedule(jnp.asarray(1000), 1000, warmup_steps=100)
        assert float(s0) < 0.02
        assert 0.1 < float(s_mid) < 1.0
        assert float(s_end) == pytest.approx(0.1, rel=1e-3)


class TestCompression:
    @given(st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_int8_roundtrip_bounded_error(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10))
        q, scale = int8_compress(x)
        err = np.abs(np.asarray(int8_decompress(q, scale) - x))
        assert err.max() <= float(scale) / 2 + 1e-9

    def test_error_feedback_converges(self):
        """With error feedback the accumulated compressed sum converges to
        the accumulated true sum (bias vanishes)."""
        rng = np.random.default_rng(0)
        g_true = jnp.zeros(256)
        g_hat_sum = jnp.zeros(256)
        res = jnp.zeros(256)
        total = jnp.zeros(256)
        for _ in range(60):
            g = jnp.asarray(rng.normal(size=256))
            total = total + g
            g_hat, res = topk_roundtrip_with_feedback(g, res, frac=0.1)
            g_hat_sum = g_hat_sum + g_hat
        # residual stays bounded -> sums track each other
        gap = float(jnp.linalg.norm(total - g_hat_sum))
        assert gap == pytest.approx(float(jnp.linalg.norm(res)), rel=1e-4)
        assert gap < 0.2 * float(jnp.linalg.norm(total))


class TestData:
    def test_token_sources_are_distinct(self):
        a = TokenSource(0, 512, 64, seed=1).sample(200)
        b = TokenSource(1, 512, 64, seed=1).sample(200)
        ha = np.bincount(a.reshape(-1), minlength=512) / a.size
        hb = np.bincount(b.reshape(-1), minlength=512) / b.size
        tv = 0.5 * np.abs(ha - hb).sum()
        assert tv > 0.3  # clearly non-IID across CUs

    def test_traffic_source_shapes_and_range(self):
        src = TrafficSource(0, seed=2)
        x, y = src.sample(32)
        assert x.shape == (32, 4) and y.shape == (32,)
        assert (x >= 0).all() and (x <= 1).all()

    def test_sampler_composition_and_weights(self):
        cfg = CocktailConfig(n_cu=6, n_ec=3, pair_iters=20, seed=0)
        state = init_state(cfg)
        state, rec, dec = step(cfg, DS, state)
        sources = [TokenSource(i, 128, 16, seed=0) for i in range(6)]
        sampler = CocktailSampler(cfg, sources, batch_per_ec=8)
        batch = sampler.sample(dec)
        assert batch["tokens"].shape == (24, 16)
        assert batch["weights"].shape == (24,)
        # every EC contributes exactly batch_per_ec rows
        assert np.bincount(batch["ec_ids"], minlength=3).tolist() == [8, 8, 8]
        comp = sampler.composition(dec)
        assert (comp.sum(axis=1) <= 8).all()
        # composition proportional to trained_at within rounding
        trained = np.asarray(dec.x) + np.asarray(dec.y).sum(axis=1)
        for j in range(3):
            if trained[:, j].sum() > 0:
                frac_target = trained[:, j] / trained[:, j].sum()
                frac_got = comp[j] / max(comp[j].sum(), 1)
                assert np.abs(frac_target - frac_got).max() < 0.2


class TestShardingRules:
    def test_param_pspec_divisibility_guard(self):
        from repro.parallel.sharding import param_pspec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # rank-3 attention weight
        spec = param_pspec("blocks/wq", (2, 64, 4, 16), mesh)
        assert len(spec) == 4  # stacked + 3 dims
        # odd vocab cannot shard on a >1 axis
        mesh2 = jax.make_mesh((1,), ("model",))
        spec2 = param_pspec("embed", (51865, 512), mesh2)
        assert spec2[0] in ("model", None)
