"""Parity sweep for the greedy matching kernel subsystem.

Three layers of guarantees:
  * the Pallas kernels (interpret mode on CPU) are BIT-exact against the jnp
    references in ``kernels/matching/ref.py`` — at the paper's testbed shape
    and at fleet scale, masked and unmasked;
  * the ``kernels/matching/ops.py`` dispatch layer produces identical results
    through either backend, for vmapped leading (fleet) axes, and never
    selects a masked (ragged-padded) entity;
  * the greedy results stay within the paper's 0.5-approximation bound of the
    exact Thm.-1 / Thm.-2 oracles (``core/oracle``).

The large-N sweep is tier2 (interpret mode is a Python-level emulator; at
N=512 a single collection solve walks 512 grid steps).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import oracle
from repro.kernels.matching import ops
from repro.kernels.matching.kernel import (greedy_assignment_pallas,
                                           greedy_collection_pallas,
                                           greedy_pairing_pallas)
from repro.kernels.matching.ref import (greedy_assignment_ref,
                                        greedy_collection_ref,
                                        greedy_pairing_ref,
                                        pairing_value_matrix)

# Testbed shape (N=8, M=3) and fleet scale (N=128, M=16).
SHAPES = [(8, 3), (128, 16)]


def _logw(rng, n, m, inf_frac=0.2):
    """Log-weights with a realistic mix: positive gains, sub-threshold
    entries, and -inf (w <= 0) holes like ``_collect_skew`` produces."""
    logw = np.log(rng.uniform(0.2, 40.0, (n, m))).astype(np.float32)
    logw[rng.random((n, m)) < inf_frac] = -np.inf
    return jnp.asarray(logw)


def _solo_pair(rng, m):
    solo = jnp.asarray(rng.uniform(-1.0, 5.0, (m,)), jnp.float32)
    pair = rng.uniform(-2.0, 10.0, (m, m))
    pair = jnp.asarray((pair + pair.T) / 2.0, jnp.float32)
    return solo, pair


def _masks(rng, n, m):
    cu = (rng.random(n) > 0.3).astype(np.float32)
    ec = (rng.random(m) > 0.3).astype(np.float32)
    cu[0] = 1.0  # keep at least one real entity per axis
    ec[0] = 1.0
    return jnp.asarray(cu), jnp.asarray(ec)


def _assert_bitexact(a, b, msg=""):
    assert (np.asarray(a) == np.asarray(b)).all(), msg


class TestInterpretParity:
    """Interpret-mode Pallas vs jnp ref: bit-exact, masked and unmasked."""

    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    @pytest.mark.parametrize("masked", [False, True], ids=["dense", "masked"])
    def test_collection(self, shape, masked):
        n, m = shape
        rng = np.random.default_rng(n * 100 + m)
        logw = _logw(rng, n, m)
        if masked:
            cu, ec = _masks(rng, n, m)
            a_ref, t_ref = ops.greedy_collection(logw, cu, ec, impl="ref")
            a_pal, t_pal = ops.greedy_collection(logw, cu, ec, impl="pallas",
                                                 interpret=True)
        else:
            a_ref, t_ref = greedy_collection_ref(logw)
            a_pal = greedy_collection_pallas(logw, interpret=True)
            count = jnp.sum(a_pal, axis=0)
            t_pal = a_pal / jnp.maximum(count[None, :], 1.0)
        _assert_bitexact(a_ref, a_pal, "alpha")
        _assert_bitexact(t_ref, t_pal, "theta")

    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    @pytest.mark.parametrize("masked", [False, True], ids=["dense", "masked"])
    def test_pairing(self, shape, masked):
        _, m = shape
        rng = np.random.default_rng(m * 7 + masked)
        solo, pair = _solo_pair(rng, m)
        if masked:
            _, ec = _masks(rng, m, m)
            m_ref = ops.greedy_pairing(solo, pair, ec_mask=ec, impl="ref")
            m_pal = ops.greedy_pairing(solo, pair, ec_mask=ec, impl="pallas",
                                       interpret=True)
        else:
            m_ref = greedy_pairing_ref(solo, pair)
            m_pal = greedy_pairing_pallas(pairing_value_matrix(solo, pair),
                                          interpret=True)
        _assert_bitexact(m_ref, m_pal, "match")

    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    def test_assignment(self, shape):
        n, m = shape
        rng = np.random.default_rng(n + m)
        w = jnp.asarray(rng.uniform(-1.0, 10.0, (n, m)), jnp.float32)
        _assert_bitexact(greedy_assignment_ref(w),
                         greedy_assignment_pallas(w, interpret=True))

    def test_collection_all_negative_selects_nothing(self):
        logw = jnp.full((16, 4), -3.0, jnp.float32)
        alpha = greedy_collection_pallas(logw, interpret=True)
        assert float(jnp.sum(alpha)) == 0.0

    def test_pairing_all_negative_selects_nothing(self):
        w = -jnp.ones((6, 6), jnp.float32)
        match = greedy_pairing_pallas(w, interpret=True)
        assert float(jnp.sum(match)) == 0.0


class TestOpsDispatch:
    """The ops layer: batching, masking, impl selection."""

    def test_vmapped_leading_axis_collection(self):
        rng = np.random.default_rng(11)
        logws = jnp.stack([_logw(rng, 16, 4) for _ in range(3)])
        av, tv = ops.greedy_collection(logws, impl="ref")
        assert av.shape == (3, 16, 4)
        for k in range(3):
            ak, tk = ops.greedy_collection(logws[k], impl="ref")
            _assert_bitexact(av[k], ak)
            _assert_bitexact(tv[k], tk)

    def test_vmapped_leading_axis_pairing(self):
        rng = np.random.default_rng(12)
        solos, pairs = zip(*[_solo_pair(rng, 5) for _ in range(3)])
        solos, pairs = jnp.stack(solos), jnp.stack(pairs)
        mv = ops.greedy_pairing(solos, pairs, impl="ref")
        assert mv.shape == (3, 5, 5)
        for k in range(3):
            _assert_bitexact(mv[k], ops.greedy_pairing(solos[k], pairs[k], impl="ref"))

    def test_vmapped_masks_broadcast(self):
        """Stacked (K, N/M) masks ride along with stacked weights."""
        rng = np.random.default_rng(13)
        logws = jnp.stack([_logw(rng, 12, 4, inf_frac=0.0) for _ in range(2)])
        cus, ecs = zip(*[_masks(rng, 12, 4) for _ in range(2)])
        cus, ecs = jnp.stack(cus), jnp.stack(ecs)
        av, _ = ops.greedy_collection(logws, cus, ecs, impl="ref")
        for k in range(2):
            ak, _ = ops.greedy_collection(logws[k], cus[k], ecs[k], impl="ref")
            _assert_bitexact(av[k], ak)

    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    def test_masked_entities_never_selected(self, shape):
        n, m = shape
        rng = np.random.default_rng(n * 3 + m)
        logw = _logw(rng, n, m, inf_frac=0.0)  # everything attractive
        cu, ec = _masks(rng, n, m)
        alpha, theta = ops.greedy_collection(logw, cu, ec, impl="ref")
        alpha = np.asarray(alpha)
        assert (alpha[np.asarray(cu) == 0, :] == 0).all()
        assert (alpha[:, np.asarray(ec) == 0] == 0).all()
        solo, pair = _solo_pair(rng, m)
        match = np.asarray(ops.greedy_pairing(solo + 100.0, pair + 100.0,
                                              ec_mask=ec, impl="ref"))
        assert (match[np.asarray(ec) == 0, :] == 0).all()
        assert (match[:, np.asarray(ec) == 0] == 0).all()

    def test_unknown_impl_raises(self):
        with pytest.raises(ValueError, match="unknown matching impl"):
            ops.greedy_collection(jnp.zeros((4, 2)), impl="cuda")


class TestApproximationBound:
    """Greedy vs the exact Thm.-1/Thm.-2 oracles: within 0.5-approximation."""

    @pytest.mark.parametrize("trial", range(4))
    def test_collection_half_approx(self, trial):
        rng = np.random.default_rng(trial)
        n, m = 7, 3
        logw_np = np.log(rng.uniform(0.2, 40.0, (n, m)))
        alpha = np.asarray(ops.greedy_collection(
            jnp.asarray(logw_np, jnp.float32), impl="pallas", interpret=True)[0])
        g_obj = oracle.collection_objective(logw_np, alpha)
        e_alpha, _ = oracle.exact_collection(logw_np)
        e_obj = oracle.collection_objective(logw_np, np.asarray(e_alpha))
        assert e_obj >= g_obj - 1e-6
        if e_obj > 0:
            assert g_obj >= 0.5 * e_obj - 1e-6

    @pytest.mark.parametrize("trial", range(4))
    def test_pairing_half_approx(self, trial):
        rng = np.random.default_rng(100 + trial)
        m = 6
        solo = rng.uniform(0.0, 5.0, m)
        pair = rng.uniform(0.0, 10.0, (m, m))
        pair = (pair + pair.T) / 2.0
        match = np.asarray(ops.greedy_pairing(
            jnp.asarray(solo, jnp.float32), jnp.asarray(pair, jnp.float32),
            impl="pallas", interpret=True))
        g_val = (np.diagonal(match) * solo).sum() + (np.triu(match, 1) * pair).sum()
        e_match = np.asarray(oracle.exact_pairing(solo, pair))
        e_val = (np.diagonal(e_match) * solo).sum() + (np.triu(e_match, 1) * pair).sum()
        assert g_val >= 0.5 * e_val - 1e-6


def _unpruned_exact_collection(logw):
    """The pre-fix Thm.-1 construction with ALL n_cu virtual-copy edges per
    (i, j) — including the non-positive ones ``oracle.exact_collection`` now
    prunes. Fixture proving the pruning never changes the objective."""
    import networkx as nx

    n_cu, n_ec = logw.shape
    g = nx.Graph()
    for i in range(n_cu):
        for j in range(n_ec):
            if not np.isfinite(logw[i, j]):
                continue
            for n in range(1, n_cu + 1):
                pen = n * math.log(n) - (n - 1) * (math.log(n - 1) if n > 1 else 0.0)
                g.add_edge(("cu", i), ("ec", j, n), weight=float(logw[i, j]) - pen)
    match = nx.max_weight_matching(g, maxcardinality=False)
    alpha = np.zeros((n_cu, n_ec), np.float32)
    for a, b in match:
        if a[0] == "ec":
            a, b = b, a
        alpha[a[1], b[1]] = 1.0
    return alpha


def test_oracle_edge_pruning_preserves_objective():
    """Fixed-seed regression for the pruned Thm.-1 graph: dropping the
    non-positive virtual-copy edges (blossom with maxcardinality=False never
    picks them) leaves the optimal objective unchanged — on weight mixes
    where most copies ARE non-positive."""
    rng = np.random.default_rng(12345)
    for trial in range(4):
        # wide range straddling zero: many (i, j, n) edges have wt <= 0
        logw = np.log(rng.uniform(0.05, 8.0, size=(6, 3)))
        logw[rng.random(logw.shape) < 0.25] = -np.inf
        pruned_alpha, _ = oracle.exact_collection(logw)
        pruned_obj = oracle.collection_objective(logw, np.asarray(pruned_alpha))
        full_obj = oracle.collection_objective(logw, _unpruned_exact_collection(logw))
        assert pruned_obj == pytest.approx(full_obj, rel=1e-9, abs=1e-9), trial


@pytest.mark.tier2
class TestLargeNSweep:
    """Interpret mode walks the full sequential grid — slow, weekly only."""

    @pytest.mark.parametrize("shape", [(512, 16), (512, 8), (256, 3)], ids=str)
    def test_collection_large(self, shape):
        n, m = shape
        rng = np.random.default_rng(n + m)
        logw = _logw(rng, n, m)
        a_ref, _ = greedy_collection_ref(logw)
        a_pal = greedy_collection_pallas(logw, interpret=True)
        _assert_bitexact(a_ref, a_pal)

    @pytest.mark.parametrize("m", [32, 64])
    def test_pairing_large(self, m):
        rng = np.random.default_rng(m)
        solo, pair = _solo_pair(rng, m)
        _assert_bitexact(greedy_pairing_ref(solo, pair),
                         greedy_pairing_pallas(pairing_value_matrix(solo, pair),
                                               interpret=True))
