"""Branch-free policy dispatch (SWITCHED) + SliceJob/from_jobs frontend.

Contracts:
  * spec-equivalence: for EVERY jittable spec in ALL_SPECS, the lax.switch
    dispatch path (policy leaves via with_policy) reproduces the Python-static
    dispatch path bit-exactly on CPU — single-slice, and composed with ragged
    padding;
  * a mixed-policy fleet (>=3 distinct jittable specs, one ragged shape)
    compiles to ONE program (jit cache count) and each slice's trace matches
    its standalone run(cfg, spec, T) — bit-exact for the padded single-slice
    path, float32-reassociation tolerance on the vmapped fleet path (same
    harness style as tests/test_ragged_fleet.py);
  * from_configs/from_ragged_configs shims and from_params validation.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (ALL_SPECS, COLLECTION_POLICIES, DS, DS_EXACT, EC_SELF,
                        LDS, NO_LSA, NO_SDC, SWITCHED, SWITCHED_NOAID,
                        TRAINING_POLICIES, CocktailConfig, FleetEngine,
                        PolicyTable, ShapeConfig, SliceJob, SliceParams,
                        init_state, run, stack_slice_params, with_policy)
from repro.core.fleet import _fleet_scan, slice_records, trim_state, unstack

BASE = CocktailConfig(n_cu=6, n_ec=3, eps=0.1, pair_iters=15, seed=7,
                      f_base=(8000.0, 20000.0, 12000.0))
SLOTS = 10
JITTABLE = [s for s in ALL_SPECS.values() if not s.exact]


def _switched_run(cfg, spec, n_slots, pad_shape=None, switch_spec=SWITCHED):
    shape = cfg.shape if pad_shape is None else pad_shape
    params = with_policy(SliceParams.from_config(cfg, pad_shape=pad_shape), spec)
    state = init_state(shape, params, seed=cfg.seed)
    return run(shape, switch_spec, n_slots, state=state, params=params)


# Shared with the ragged-fleet harness: identical record-equality contract.
from test_ragged_fleet import _assert_records_equal  # noqa: E402


def _assert_state_equal(st_got, st_ref, exact=True):
    """Like test_ragged_fleet's state helper but also pins emp_mults (the
    learning-aid gate is what this file is about)."""
    assert_eq = (np.testing.assert_array_equal if exact else
                 lambda b, a, err_msg: np.testing.assert_allclose(
                     b, a, rtol=1e-4, atol=1e-2, err_msg=err_msg))
    for name in ("q", "r", "omega"):
        assert_eq(np.asarray(getattr(st_got.queues, name)),
                  np.asarray(getattr(st_ref.queues, name)), err_msg=name)
    for name in ("mu", "eta", "phi", "lam"):
        assert_eq(np.asarray(getattr(st_got.mults, name)),
                  np.asarray(getattr(st_ref.mults, name)), err_msg=name)
        assert_eq(np.asarray(getattr(st_got.emp_mults, name)),
                  np.asarray(getattr(st_ref.emp_mults, name)),
                  err_msg=f"emp_{name}")


# --------------------------------------------------------------------------
# Spec-equivalence sweep: switch dispatch == static dispatch, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", JITTABLE, ids=lambda s: s.name)
def test_switched_matches_static_bitexact(spec):
    st_ref, recs_ref = run(BASE, spec, SLOTS)
    st_sw, recs_sw = _switched_run(BASE, spec, SLOTS)
    _assert_records_equal(recs_sw, recs_ref, exact=True)
    _assert_state_equal(st_sw, st_ref, exact=True)


@pytest.mark.parametrize("spec", [s for s in JITTABLE if not s.learning_aid],
                         ids=lambda s: s.name)
def test_switched_noaid_matches_static_bitexact(spec):
    """SWITCHED_NOAID (virtual path compiled out) is equally bit-exact for
    every spec without the learning aid (whose emp_mults stay frozen on the
    static path too)."""
    st_ref, recs_ref = run(BASE, spec, SLOTS)
    st_sw, recs_sw = _switched_run(BASE, spec, SLOTS,
                                   switch_spec=SWITCHED_NOAID)
    _assert_records_equal(recs_sw, recs_ref, exact=True)
    _assert_state_equal(st_sw, st_ref, exact=True)


@pytest.mark.parametrize("spec", [DS, LDS, NO_LSA], ids=lambda s: s.name)
def test_switched_composes_with_ragged_padding(spec):
    """switch dispatch x entity-mask padding: still bit-exact vs the plain
    unpadded static run."""
    pad = ShapeConfig(n_cu=9, n_ec=5, pair_iters=BASE.pair_iters)
    st_ref, recs_ref = run(BASE, spec, SLOTS)
    st_sw, recs_sw = _switched_run(BASE, spec, SLOTS, pad_shape=pad)
    _assert_records_equal(recs_sw, recs_ref, exact=True)
    _assert_state_equal(trim_state(st_sw, BASE.shape), st_ref, exact=True)


def test_switched_requires_policy_leaves():
    # from_config defaults the leaves (to DS); hand-built params may not
    stripped = BASE.params._replace(collect_id=None, train_id=None,
                                    use_lsa=None, learning_aid=None)
    state = init_state(BASE.shape, stripped, seed=0)
    with pytest.raises(TypeError, match="policy leaves"):
        run(BASE.shape, SWITCHED, 2, state=state, params=stripped)


def test_from_config_defaults_policy_leaves_to_ds():
    p = BASE.params
    assert int(p.collect_id) == COLLECTION_POLICIES.index(DS.collection)
    assert int(p.train_id) == TRAINING_POLICIES.index(DS.training)
    assert float(p.use_lsa) == 1.0 and float(p.learning_aid) == 0.0
    np.testing.assert_array_equal(
        np.asarray(with_policy(p, DS).collect_id), np.asarray(p.collect_id))


# --------------------------------------------------------------------------
# Policy tables
# --------------------------------------------------------------------------

def test_policy_table_registry():
    assert COLLECTION_POLICIES.names == ("skew", "plain", "cufull")
    assert TRAINING_POLICIES.names == ("skew", "linear", "solo", "ecfull")
    assert COLLECTION_POLICIES.index("plain") == 1
    assert "solo" in TRAINING_POLICIES and "solo" not in COLLECTION_POLICIES
    assert len(COLLECTION_POLICIES.fns) == len(COLLECTION_POLICIES)
    with pytest.raises(KeyError, match="unknown collection policy"):
        COLLECTION_POLICIES.index("nope")
    t = PolicyTable("demo")
    t.register("a")(lambda: None)
    with pytest.raises(ValueError, match="already registered"):
        t.register("a")(lambda: None)


def test_with_policy_leaves():
    p = with_policy(BASE.params, NO_SDC)
    assert int(p.collect_id) == COLLECTION_POLICIES.index("plain")
    assert int(p.train_id) == TRAINING_POLICIES.index("skew")
    assert float(p.use_lsa) == 1.0 and float(p.learning_aid) == 0.0
    with pytest.raises(ValueError):
        with_policy(BASE.params, DS_EXACT)
    with pytest.raises(ValueError):
        with_policy(BASE.params, SWITCHED)


# --------------------------------------------------------------------------
# Mixed-policy fleets (acceptance)
# --------------------------------------------------------------------------

def _mixed_jobs():
    return [
        SliceJob(BASE, DS, name="prod/ds"),
        SliceJob(CocktailConfig(n_cu=8, n_ec=4, pair_iters=15, seed=1,
                                zeta=800.0), NO_SDC, name="canary/no-sdc"),
        SliceJob(dataclasses.replace(BASE, eps=0.2, seed=2), LDS),
        SliceJob(CocktailConfig(n_cu=5, n_ec=2, pair_iters=15, seed=3), NO_LSA),
        SliceJob(dataclasses.replace(BASE, seed=4), EC_SELF),
    ]


def test_mixed_policy_ragged_fleet_matches_standalone():
    """>=3 distinct jittable specs + ragged shapes in ONE program; every
    slice's (T,) trace matches its standalone run (vmap may re-associate
    float32 reductions: same tolerance as tests/test_fleet.py)."""
    jobs = _mixed_jobs()
    eng = FleetEngine.from_jobs(jobs)
    assert eng.spec.name == "switched"
    assert eng.shape == ShapeConfig(n_cu=8, n_ec=4, pair_iters=15)
    assert eng.slice_specs == tuple(j.spec for j in jobs)
    st, recs = eng.run(SLOTS)
    assert recs.cost.shape == (SLOTS, len(jobs))
    for k, job in enumerate(jobs):
        st_ref, recs_ref = run(job.config, job.spec, SLOTS)
        _assert_records_equal(slice_records(recs, k), recs_ref, exact=False)
        _assert_state_equal(trim_state(unstack(st, k), job.config.shape),
                            st_ref, exact=False)


def test_mixed_policy_fleet_compiles_one_program():
    # The jit cache is process-global; clear it so an earlier compile of the
    # same (shape, spec, n_slots) key can't turn the run into a cache hit.
    _fleet_scan._clear_cache()
    before = _fleet_scan._cache_size()
    eng = FleetEngine.from_jobs(_mixed_jobs())
    eng.run(3)
    assert _fleet_scan._cache_size() - before == 1


def test_mixed_noaid_fleet_drops_virtual_path_and_matches():
    """No L-DS slice -> from_jobs picks SWITCHED_NOAID (virtual updates
    compiled out); the mixed fleet still matches standalone runs."""
    jobs = [SliceJob(BASE, DS),
            SliceJob(dataclasses.replace(BASE, seed=1), NO_SDC),
            SliceJob(dataclasses.replace(BASE, seed=2), EC_SELF)]
    eng = FleetEngine.from_jobs(jobs)
    assert eng.spec == SWITCHED_NOAID
    st, recs = eng.run(SLOTS)
    for k, job in enumerate(jobs):
        st_ref, recs_ref = run(job.config, job.spec, SLOTS)
        _assert_records_equal(slice_records(recs, k), recs_ref, exact=False)
        _assert_state_equal(unstack(st, k), st_ref, exact=False)


def test_from_jobs_homogeneous_policy_stays_static():
    """One policy tuple (even via distinct spec names, e.g. DS==GREEDY) keeps
    the Python-static dispatch path — no switch overhead, params bit-identical
    to the from_configs shim."""
    from repro.core import GREEDY

    cfgs = [BASE, dataclasses.replace(BASE, seed=1, zeta=700.0)]
    eng = FleetEngine.from_jobs([SliceJob(cfgs[0], DS), SliceJob(cfgs[1], GREEDY)])
    assert eng.spec == DS
    assert (np.asarray(eng.params.collect_id) == 0).all()
    shim = FleetEngine.from_configs(cfgs, DS)
    assert shim.spec == DS
    for a, b in zip(eng.params, shim.params):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_from_jobs_accepts_bare_configs_and_rejects_bad_jobs():
    eng = FleetEngine.from_jobs([BASE, dataclasses.replace(BASE, seed=1)], NO_LSA)
    assert eng.spec == NO_LSA and eng.n_slices == 2
    with pytest.raises(ValueError):
        FleetEngine.from_jobs([])
    with pytest.raises(ValueError, match="exact"):
        SliceJob(BASE, DS_EXACT)
    with pytest.raises(ValueError, match="concrete"):
        SliceJob(BASE, SWITCHED)
    with pytest.raises(TypeError):
        FleetEngine.from_jobs(["not-a-job"])


def test_slicejob_seed_resolution():
    assert SliceJob(BASE).resolved_seed == BASE.seed
    assert SliceJob(BASE, seed=42).resolved_seed == 42
    eng = FleetEngine.from_jobs([SliceJob(BASE, seed=42)])
    assert eng.seeds == (42,)


# --------------------------------------------------------------------------
# Satellites: from_params validation + Decision.duty/collected
# --------------------------------------------------------------------------

def test_from_params_rejects_unstacked_pytree():
    with pytest.raises(ValueError, match="unstacked"):
        FleetEngine.from_params(BASE.shape, BASE.params, DS)


def test_from_params_rejects_inconsistent_leading_axis():
    stacked = stack_slice_params([BASE.params, BASE.params])
    bad = stacked._replace(zeta=stacked.zeta[:1])
    with pytest.raises(ValueError, match="zeta"):
        FleetEngine.from_params(BASE.shape, bad, DS)


def test_from_params_valid_roundtrip():
    stacked = stack_slice_params(
        [BASE.params, dataclasses.replace(BASE, eps=0.3).params])
    eng = FleetEngine.from_params(BASE.shape, stacked, DS, seeds=(1, 2))
    assert eng.n_slices == 2


def test_decision_duty_and_collected():
    import jax

    from repro.core import step

    state = init_state(BASE.shape, BASE.params, seed=0)
    rng = jax.random.split(state.rng)[1]
    from repro.core import sample_network_state
    net = sample_network_state(rng, BASE.shape, state.t, BASE.params)
    _, _, dec = step(BASE.shape, DS, state, net=net, params=BASE.params)
    np.testing.assert_array_equal(np.asarray(dec.duty),
                                  np.asarray(dec.alpha * dec.theta))
    np.testing.assert_array_equal(np.asarray(dec.collected(net)),
                                  np.asarray(dec.alpha * dec.theta * net.d))
    assert not isinstance(type(dec).collected, property)
