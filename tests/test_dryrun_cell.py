"""Dry-run machinery under pytest: lower+compile one real cell per step kind
on the production 512-device mesh (subprocess: XLA flags precede jax init)."""
import json
import pathlib
import subprocess
import sys

import pytest


def _run_cell(tmp_path, arch, shape, mesh="pod", style="tp"):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--style", style,
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stdout + r.stderr
    tag = f"{arch}_{shape}_{mesh}".replace(".", "_")
    if style != "tp":
        tag += f"_{style}"
    return json.loads((pathlib.Path(tmp_path) / f"{tag}.json").read_text())


def test_train_cell_whisper(tmp_path):
    d = _run_cell(tmp_path, "whisper-base", "train_4k")
    assert d["kind"] == "train"
    rf = d["roofline"]
    assert rf["compute_s"] > 0 and rf["collective_s"] >= 0
    assert d["cost"]["flops_per_device"] > 1e12  # trip counts applied
    assert d["memory"]["peak_bytes"] > 0


def test_decode_cell_multipod(tmp_path):
    d = _run_cell(tmp_path, "whisper-base", "decode_32k", mesh="multipod")
    assert d["mesh"] == "2x16x16" and d["n_chips"] == 512
    assert d["analytic_memory"]["fits_hbm"]


def test_skip_rule_applied(tmp_path):
    d = _run_cell(tmp_path, "minitron-4b", "long_500k")
    assert d.get("skipped") is True
