"""End-to-end behaviour of DataSche / L-DS and the paper's qualitative claims."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (CU_FULL, DS, DS_EXACT, EC_FULL, EC_SELF, LDS, NO_LSA,
                        NO_SDC, NO_SLT, CocktailConfig, init_state, run, step)
from repro.core import metrics

CFG = CocktailConfig(n_cu=10, n_ec=4, eps=0.1, pair_iters=30, seed=5,
                     f_base=(8000.0, 14000.0, 20000.0, 48000.0))


def _check_decision_feasible(cfg, dec, net, queues, one_peer=True, one_conn=True):
    alpha = np.asarray(dec.alpha)
    theta = np.asarray(dec.theta)
    x, y, z = np.asarray(dec.x), np.asarray(dec.y), np.asarray(dec.z)
    m = cfg.n_ec
    if one_conn:
        # (2) each CU <= 1 connection
        assert (alpha.sum(axis=1) <= 1 + 1e-5).all()
    # (3) per-EC total duration <= 1
    assert ((alpha * theta).sum(axis=0) <= 1 + 1e-4).all()
    # (5) each EC at most one peer (z symmetric) — removed by ECFull by design
    np.testing.assert_allclose(z, z.T, atol=1e-6)
    if one_peer:
        assert (z.sum(axis=1) <= 1 + 1e-5).all()
    # (6) pairwise flow within link capacity
    flow = y.sum(axis=0)
    total = flow + flow.T
    assert (total <= np.asarray(net.cap_d) * (1 + 1e-3) + 1e-2).all()
    # (7) offloading only along established connections
    assert (y.sum(axis=0)[z < 0.5] <= 1e-4).all()
    # (8) compute budget
    trained = x.sum(axis=0) + y.sum(axis=(0, 1))
    assert (trained <= np.asarray(net.f) / cfg.rho * (1 + 1e-3) + 1e-2).all()
    # (13) queue caps
    dep = x + y.sum(axis=2)
    assert (dep <= np.asarray(queues.r) * (1 + 1e-3) + 1e-3).all()
    # nonnegativity
    assert (x >= -1e-6).all() and (y >= -1e-6).all() and (theta >= -1e-6).all()


@pytest.mark.parametrize("spec", [DS, LDS, NO_SDC, NO_SLT, NO_LSA, EC_FULL, EC_SELF, CU_FULL],
                         ids=lambda s: s.name)
def test_per_slot_feasibility(spec):
    state = init_state(CFG)
    from repro.core.network import sample_network_state
    import jax
    for t in range(6):
        key = jax.random.fold_in(jax.random.PRNGKey(42), t)
        net = sample_network_state(key, CFG, state.t)
        new_state, rec, dec = step(CFG, spec, state, net)
        _check_decision_feasible(CFG, dec, net, state.queues,
                                 one_peer=spec.training != "ecfull",
                                 one_conn=spec.collection != "cufull")
        # queues never negative
        assert (np.asarray(new_state.queues.q) >= -1e-4).all()
        assert (np.asarray(new_state.queues.r) >= -1e-4).all()
        state = new_state


def test_queue_multiplier_equivalence():
    """Paper remark (Sec. III-A): queue backlog == multiplier / eps. Our sim
    adds a Q-availability cap that can cause small transient deviations, so we
    check strong correlation + matched scale instead of exact equality."""
    st, _ = run(CFG, DS, 40)
    q = np.asarray(st.queues.q)
    mu = np.asarray(st.mults.mu) / CFG.eps
    corr = np.corrcoef(q, mu)[0, 1]
    assert corr > 0.95
    assert np.abs(np.log(q.sum() / mu.sum())) < 0.5


def test_skew_amendment_effect():
    """Long-term skew amendment keeps the skew degree bounded; removing it
    (NO-LSA) yields a strictly larger terminal skew (paper Fig. 5/7 claim)."""
    st_ds, _ = run(CFG, DS, 80)
    st_no, _ = run(CFG, NO_LSA, 80)
    s_ds = metrics.summary(CFG, st_ds)["skew_degree"]
    s_no = metrics.summary(CFG, st_no)["skew_degree"]
    assert s_ds < s_no


def test_collection_evenness_vs_nosdc():
    """Skew-aware collection spreads uploads across CUs (paper Fig. 5).

    Run in the figure's capacity-limited regime — arrivals exceed upload
    capacity, so CUs stay backlogged and cumulative uploads reflect the
    *collection policy*. (In an arrival-limited run any queue-stabilizing
    policy converges to uploads == arrivals, so the comparison there only
    measures transient noise; with persistent link heterogeneity the raw
    stdev additionally rewards whichever policy collects less overall, hence
    the scale-free CV.)"""
    cfg = dataclasses.replace(CFG, q0=50000.0, zeta=1500.0)
    st_ds, _ = run(cfg, DS, 60)
    st_no, _ = run(cfg, NO_SDC, 60)

    def cv(state):
        up = np.asarray(state.uploaded)
        return up.std() / up.mean()

    assert cv(st_ds) < cv(st_no)


def test_backlog_eps_tradeoff():
    """Thm. 3: backlog = O(1/eps) -> larger eps gives smaller backlog."""
    small = dataclasses.replace(CFG, eps=0.05)
    large = dataclasses.replace(CFG, eps=0.4)
    st_s, _ = run(small, DS, 60)
    st_l, _ = run(large, DS, 60)
    back_s = float(st_s.queues.q.sum() + st_s.queues.r.sum())
    back_l = float(st_l.queues.q.sum() + st_l.queues.r.sum())
    assert back_l < back_s


def test_lds_reduces_backlog():
    """L-DS's empirical multipliers act as virtual backlog -> faster queue
    drain at the same eps (paper Fig. 8(b)(c))."""
    cfg = dataclasses.replace(CFG, eps=0.05)
    st_ds, _ = run(cfg, DS, 60)
    st_lds, _ = run(cfg, LDS, 60)
    assert float(st_lds.queues.q.sum()) < float(st_ds.queues.q.sum())
    assert float(st_lds.total_trained) > float(st_ds.total_trained)


def test_cufull_costs_more():
    """CU-EC full connection ignores capacity/backlog -> worse unit cost
    (paper Fig. 9: up to 43.7% reduction for DS)."""
    st_ds, _ = run(CFG, DS, 60)
    st_cf, _ = run(CFG, CU_FULL, 60)
    assert metrics.unit_cost(st_ds) < metrics.unit_cost(st_cf)


def test_exact_mode_runs_and_is_competitive():
    cfg = CocktailConfig(n_cu=6, n_ec=3, eps=0.1, pair_iters=30, seed=3)
    st_exact, _ = run(cfg, DS_EXACT, 8)
    st_greedy, _ = run(cfg, DS, 8)
    # exact matching should not be much worse on unit cost than greedy
    ratio = metrics.unit_cost(st_exact) / metrics.unit_cost(st_greedy)
    assert 0.5 < ratio < 2.0


def test_deterministic_given_seed():
    st1, _ = run(CFG, DS, 10)
    st2, _ = run(CFG, DS, 10)
    np.testing.assert_allclose(np.asarray(st1.queues.q), np.asarray(st2.queues.q))
    assert float(st1.total_cost) == float(st2.total_cost)


class TestPersistentHeterogeneity:
    """Regression for the het-resampling bug: ``link_het``/``ec_het`` and the
    diurnal ``phase`` must be identical across slots t and t+1 (they derive
    from the slot-invariant ``het_key``), while the noise terms stay i.i.d.
    per slot. Before the fix they were drawn from the per-slot key, so the
    capacity heterogeneity driving the paper's data-skew problem never
    persisted."""

    def _setup(self):
        import jax
        from repro.core.types import het_key_from_seed, split_config
        shape, params = split_config(CFG)
        return jax, shape, params, het_key_from_seed(CFG.seed)

    def test_het_and_phase_identical_across_slots_noise_differs(self):
        jax, shape, params, hk = self._setup()
        import jax.numpy as jnp
        from repro.core.network import heterogeneity, sample_network_state

        # What slots t and t+1 actually use: step threads state.het_key (held
        # constant, asserted below) into the sampler, whose heterogeneity is a
        # pure function of it — so link_het/ec_het/phase are slot-invariant.
        h_t = heterogeneity(hk, shape.n_cu, shape.n_ec)
        h_t1 = heterogeneity(hk, shape.n_cu, shape.n_ec)
        for name, a, b in zip(h_t._fields, h_t, h_t1):
            assert (np.asarray(a) == np.asarray(b)).all(), name

        # ... while everything drawn from the per-slot key still differs.
        k_t = jax.random.fold_in(jax.random.PRNGKey(42), 0)
        k_t1 = jax.random.fold_in(jax.random.PRNGKey(42), 1)
        net_t = sample_network_state(k_t, shape, jnp.asarray(0), params, het_key=hk)
        net_t1 = sample_network_state(k_t1, shape, jnp.asarray(1), params, het_key=hk)
        assert not np.allclose(np.asarray(net_t.c), np.asarray(net_t1.c))
        assert not np.allclose(np.asarray(net_t.d), np.asarray(net_t1.d))

        # het_key is live, not decorative: a different one changes capacity.
        from repro.core.types import het_key_from_seed
        net_other = sample_network_state(k_t, shape, jnp.asarray(0), params,
                                         het_key=het_key_from_seed(CFG.seed + 1))
        assert not np.allclose(np.asarray(net_t.d), np.asarray(net_other.d))

    def test_step_carries_het_key_unchanged(self):
        state = init_state(CFG)
        s1, _, _ = step(CFG, DS, state)
        s2, _, _ = step(CFG, DS, s1)
        assert state.het_key is not None
        assert (np.asarray(state.het_key) == np.asarray(s1.het_key)).all()
        assert (np.asarray(s1.het_key) == np.asarray(s2.het_key)).all()

    def test_capacity_time_mean_tracks_link_het(self):
        """Persistence is visible in the data: averaged over a full diurnal
        period, per-link capacity is ordered by the persistent multiplier.
        Under the old bug the time-mean was flat (corr ~ 0)."""
        jax, shape, params, hk = self._setup()
        import jax.numpy as jnp
        from repro.core.network import heterogeneity, sample_network_state

        sampler = jax.jit(lambda k, t: sample_network_state(
            k, shape, t, params, het_key=hk).d)
        base = jax.random.PRNGKey(7)
        # 96 slots spaced 3 apart span the 288-slot diurnal period, so the
        # per-link phase offsets average out of the mean.
        ds = np.stack([np.asarray(sampler(jax.random.fold_in(base, s),
                                          jnp.asarray(3 * s)))
                       for s in range(96)])
        het = np.asarray(heterogeneity(hk, shape.n_cu, shape.n_ec).link_het)
        corr = np.corrcoef(ds.mean(axis=0).ravel(), het.ravel())[0, 1]
        assert corr > 0.8, corr

    def test_heterogeneity_padding_invariant(self):
        """Entity-keyed het draws: padding to a larger shape leaves the real
        block bit-identical (the ragged-fleet invariant)."""
        _, shape, _, hk = self._setup()
        from repro.core.network import heterogeneity

        n, m = shape.n_cu, shape.n_ec
        small = heterogeneity(hk, n, m)
        big = heterogeneity(hk, n + 3, m + 2)
        assert (np.asarray(big.link_het)[:n, :m] == np.asarray(small.link_het)).all()
        assert (np.asarray(big.phase_d)[:n, :m] == np.asarray(small.phase_d)).all()
        assert (np.asarray(big.ec_het)[:m, :m] == np.asarray(small.ec_het)).all()
