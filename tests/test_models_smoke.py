"""Per-architecture smoke tests: reduced config, one forward + one grad step
+ a few decode steps on CPU; assert shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, reduced
from repro.models import build_model

ARCHS = sorted(all_configs())
B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "labels": jnp.roll(tokens, -1, axis=1),
        "weights": jnp.asarray([1.0, 0.5], jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(ks[1], (B, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.enc_ctx, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(all_configs()[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits = jax.jit(model.forward)(params, batch)
    s_total = S + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()

    (loss, aux), grads = jax.jit(jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in flat)))
    assert gnorm > 0, "no gradient signal"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_steps(arch):
    cfg = reduced(all_configs()[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, max_len=8)
    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.enc_ctx, cfg.d_model))
        cache = encdec.prefill_cross(cfg, params, frames, cache)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((B, 1), jnp.int32)
    for t in range(4):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)
        assert int(cache["pos"]) == t + 1


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce full-seq forward logits (dense)."""
    cfg = reduced(all_configs()["qwen2.5-32b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": tokens})
    cache = model.init_cache(B, max_len=8)
    outs = []
    for t in range(6):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = reduced(all_configs()["falcon-mamba-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": tokens})
    cache = model.init_cache(B, max_len=8)
    outs = []
    for t in range(6):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-3, atol=2e-3)


def test_sliding_window_limits_attention():
    """With window w, token t must be independent of tokens < t - w + 1."""
    import dataclasses
    cfg = dataclasses.replace(reduced(all_configs()["mixtral-8x7b"]),
                              sliding_window=4, n_experts=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)  # perturb far past
    l1 = model.forward(params, {"tokens": t1})
    l2 = model.forward(params, {"tokens": t2})
    # positions >= 2*window away from the perturbed token are unaffected
    # (information propagates one window per layer; use last position w/ 2 layers)
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-4, atol=1e-4)
