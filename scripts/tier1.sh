#!/usr/bin/env bash
# Single entrypoint for the ROADMAP tier-1 verify, for builders and CI alike:
#
#   scripts/tier1.sh [extra pytest args...]
#
# Installs the dev requirements when pip + network are available (best-effort:
# hypothesis-gated modules skip cleanly without them) and runs the suite with
# PYTHONPATH=src from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${TIER1_SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install -q -r requirements-dev.txt 2>/dev/null \
        || echo "tier1: dev requirements unavailable (offline?); continuing" >&2
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
