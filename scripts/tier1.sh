#!/usr/bin/env bash
# Single entrypoint for the ROADMAP tier-1 verify, for builders and CI alike:
#
#   scripts/tier1.sh [extra pytest args...]        # tier-1: skips tier2 marks
#   TIER=2 scripts/tier1.sh [extra pytest args...] # full suite incl. tier2
#
# Installs the dev requirements when pip + network are available (best-effort:
# hypothesis-gated modules skip cleanly without them) and runs the suite with
# PYTHONPATH=src from the repo root. The heavy hypothesis sweeps are marked
# tier2 (see pytest.ini) and deselected from the default gate.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${TIER1_SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install -q -r requirements-dev.txt 2>/dev/null \
        || echo "tier1: dev requirements unavailable (offline?); continuing" >&2
fi

MARK_ARGS=(-m "not tier2")
if [[ "${TIER:-1}" == "2" ]]; then
    MARK_ARGS=()
fi

# ${arr[@]+...} guard: empty-array expansion trips `set -u` on bash < 4.4
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m pytest -x -q ${MARK_ARGS[@]+"${MARK_ARGS[@]}"} "$@"
