"""Mixed-policy fleets: one switch-dispatch program vs per-spec sub-fleets.

Before branch-free dispatch, a slice population mixing AlgoSpecs (the staged
rollout: skew-aware production next to greedy / no-LSA canaries) had to run
one compiled fleet PER spec. ``FleetEngine.from_jobs`` runs the whole mix in
ONE program: policy choice is ``lax.switch`` over the indexed policy tables.
The structural win is 1 compiled program instead of n_specs and a single
shardable K axis; the cost is that every slot carries all policy branches and
the always-on learning-aid virtual path. This benchmark records both sides —
wall-clock per slot and compile counts vs K and n_specs — as ``BENCH {...}``
JSON rows so the trade is tracked across PRs.
"""
from __future__ import annotations

import time

import jax

from repro.core import DS, LDS, NO_LSA, NO_SDC, CocktailConfig, FleetEngine, SliceJob
from repro.core.fleet import _fleet_scan

from .common import emit, emit_json

SPEC_POOL = (DS, NO_SDC, NO_LSA, LDS)


def _mixed_jobs(k: int, n_specs: int) -> list[SliceJob]:
    """K slices cycling over n_specs distinct AlgoSpecs, heterogeneous params
    at testbed-like shape (dispatch-dominated, the PR 1 batching regime)."""
    specs = SPEC_POOL[:n_specs]
    return [
        SliceJob(
            CocktailConfig(
                n_cu=8, n_ec=3, pair_iters=20, seed=s,
                zeta=400.0 + 60.0 * (s % 5), eps=0.1 + 0.02 * (s % 3),
                f_base=tuple(8000.0 + 4000.0 * ((s + j) % 4) for j in range(3)),
            ),
            specs[s % n_specs], name=f"slice-{s}")
        for s in range(k)
    ]


def _timed_run(engines, slots: int, repeat: int) -> float:
    """Mean wall seconds to run all engines for `slots` (compile excluded)."""
    states = [eng.init() for eng in engines]
    outs = [eng.run(slots, st) for eng, st in zip(engines, states)]  # warmup
    for st, _ in outs:
        jax.block_until_ready(st.queues.q)
    t0 = time.perf_counter()
    for _ in range(repeat):
        outs = [eng.run(slots, st) for eng, st in zip(engines, states)]
        for st, _ in outs:
            jax.block_until_ready(st.queues.q)
    return (time.perf_counter() - t0) / repeat


def policy_scale(ks=(4, 8, 16), n_specs_list=(2, 4), slots: int = 8,
                 repeat: int = 3):
    rows = {}
    for n_specs in n_specs_list:
        for k in ks:
            jobs = _mixed_jobs(k, n_specs)

            # Compile counts must not leak between rows (the jit cache is
            # process-global and keyed on (shape, spec, n_slots) only).
            _fleet_scan._clear_cache()
            cache0 = _fleet_scan._cache_size()
            switched = FleetEngine.from_jobs(jobs)
            dt_switch = _timed_run([switched], slots, repeat)
            programs_switched = _fleet_scan._cache_size() - cache0

            groups: dict = {}
            for j in jobs:
                groups.setdefault(j.spec, []).append(j)
            _fleet_scan._clear_cache()
            cache0 = _fleet_scan._cache_size()
            subfleets = [FleetEngine.from_jobs(g) for g in groups.values()]
            dt_sub = _timed_run(subfleets, slots, repeat)
            programs_sub = _fleet_scan._cache_size() - cache0

            us_switch = dt_switch / slots * 1e6
            us_sub = dt_sub / slots * 1e6
            rows[(k, n_specs)] = (us_switch, us_sub)
            emit(f"policy_scale/K{k}specs{n_specs}", us_switch,
                 f"subfleets {us_sub:.0f}us ({programs_sub} programs)")
            emit_json("policy_scale", k=k, n_specs=n_specs, slots=slots,
                      us_per_slot_switched=round(us_switch, 1),
                      us_per_slot_subfleets=round(us_sub, 1),
                      programs_switched=programs_switched,
                      programs_subfleets=programs_sub,
                      switched_speedup=round(us_sub / us_switch, 3))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    policy_scale()
