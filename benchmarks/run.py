"""Benchmark harness: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV. Sections:
  fig5/fig6   skew-aware mechanism ablations (collection / training evenness)
  fig7        trained-model accuracy under scheduling ablations
  fig8        DS vs Learning-aid DS across step sizes (cost/backlog/skew)
  fig9        unit framework cost vs baselines across N / M (headline: cost
              reduction vs CUFull)
  sched_scale scheduler wall-time scaling + matching kernel
  fleet_scale K-slice fleet engine scaling (BENCH JSON rows)
  ragged_scale padded mixed-shape fleet vs per-shape sub-fleets (BENCH rows)
  policy_scale mixed-policy switch-dispatch fleet vs per-spec sub-fleets
              (wall-clock per slot + compile counts vs K and n_specs)
  matching_scale kernel-vs-reference cost of the three greedy matchers
              across N x M (BENCH rows; Pallas timings on TPU)
  roofline    aggregated dry-run roofline terms (run scripts/dryrun_sweep.sh
              first; missing artifacts are skipped gracefully)

Every BENCH row printed to stdout is also written to a ``BENCH_<name>.json``
artifact at the end of the run (common.write_bench_artifacts), so the perf
trajectory survives the CI log; the weekly workflow uploads them.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from . import (common, fig7_accuracy, fleet_scale, matching_scale,
                   paper_figs, policy_scale, ragged_scale, roofline,
                   sched_scale)

    sections = [
        ("fig5", paper_figs.fig5_collection_evenness),
        ("fig6", paper_figs.fig6_training_evenness),
        ("fig7", fig7_accuracy.fig7_accuracy),
        ("fig8", paper_figs.fig8_ds_vs_lds),
        ("fig9", paper_figs.fig9_unit_cost),
        ("sched_scale", sched_scale.sched_scale),
        ("fleet_scale", fleet_scale.fleet_scale),
        ("ragged_scale", ragged_scale.ragged_scale),
        ("policy_scale", policy_scale.policy_scale),
        ("matching_scale", matching_scale.matching_scale),
        ("matching", sched_scale.matching_kernel_bench),
        ("roofline", roofline.roofline_table),
    ]
    failures = 0
    for name, fn in sections:
        try:
            fn()
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    for path in common.write_bench_artifacts():
        print(f"artifact/{path},0,written")
    print(f"summary/sections_failed,0,{failures}")


if __name__ == "__main__":
    main()
