"""Scheduler scalability (paper Sec. III-D): per-slot wall time of the
jitted production scheduler vs (N, M), plus the matching-kernel microbench.
The paper's exact solver is O(N^3 M^3); the production greedy path is
O(N M) per selected pair with vectorised argmax — this table shows the
scaling that makes thousands of CUs schedulable every slot."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DS, CocktailConfig, init_state, step

from .common import emit, emit_json


def sched_scale():
    rows = {}
    for n_cu, n_ec in [(16, 4), (64, 8), (256, 8), (1024, 8)]:
        cfg = CocktailConfig(n_cu=n_cu, n_ec=n_ec, pair_iters=20, seed=0)
        st = init_state(cfg)
        stepper = jax.jit(lambda s: step(cfg, DS, s)[0], static_argnums=())
        st = stepper(st)  # compile
        jax.block_until_ready(st.queues.q)
        t0 = time.perf_counter()
        for _ in range(3):
            st = stepper(st)
        jax.block_until_ready(st.queues.q)
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows[(n_cu, n_ec)] = us
        emit(f"sched_scale/N{n_cu}xM{n_ec}", us, f"{us/1e3:.1f}ms/slot")
        emit_json("sched_scale", n_cu=n_cu, n_ec=n_ec, us_per_slot=round(us, 1))
    return rows


def matching_kernel_bench():
    from repro.kernels.matching.kernel import greedy_assignment_pallas
    from repro.kernels.matching.ref import greedy_assignment_ref
    for n, m in [(256, 8), (1024, 16)]:
        w = jnp.asarray(np.random.default_rng(0).uniform(0, 10, (n, m)), jnp.float32)
        ref = jax.jit(greedy_assignment_ref)
        ref(w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            ref(w).block_until_ready()
        us = (time.perf_counter() - t0) / 5 * 1e6
        emit(f"matching/jnp_greedy/N{n}xM{m}", us, "jit-cpu")
        out = greedy_assignment_pallas(w, interpret=True)
        match = bool(jnp.allclose(out, greedy_assignment_ref(w)))
        emit(f"matching/pallas_interpret_matches/N{n}xM{m}", 0, str(match).lower())
