"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import numpy as np

from repro.core import CocktailConfig


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # us


def testbed_config(**overrides) -> CocktailConfig:
    """Paper Sec. IV-A testbed scale: 6 CUs, 3 heterogeneous ECs.

    Unit calibration: our simulator expresses capacities in samples/slot
    rather than kbps, so the paper's raw cost constants (c=250) would price
    transmission above the queue-relief utility and suppress collection
    entirely; c_base=50 puts the cost/utility ratio in the paper's operating
    regime (all mechanisms bind; see the calibration probe in EXPERIMENTS.md).
    """
    base = dict(n_cu=6, n_ec=3, delta=0.02, eps=0.1, q0=5000.0, zeta=500.0,
                d_base=2000.0, cap_d_base=8000.0,
                f_base=(8000.0, 20000.0, 8000.0),
                c_base=50.0, e_base=50.0, p_base=200.0,
                pair_iters=30, seed=0)
    base.update(overrides)
    return CocktailConfig(**base)


def sim_config(n_cu=20, n_ec=5, **overrides) -> CocktailConfig:
    """Paper Sec. IV-C simulation scale."""
    base = dict(n_cu=n_cu, n_ec=n_ec, delta=0.0001, eps=0.2, q0=5000.0,
                zeta=500.0, d_base=2000.0, cap_d_base=8000.0,
                f_base=tuple(float(f) for f in np.random.default_rng(0).choice(
                    [8000, 14000, 20000, 48000], n_ec)),
                c_base=500.0, e_base=30.0, p_base=100.0,
                pair_iters=30, seed=0)
    base.update(overrides)
    return CocktailConfig(**base)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


# Every emit_json row also lands here so the harness can write per-bench
# artifact files at the end of a run (write_bench_artifacts).
_BENCH_ROWS: list[dict] = []


def emit_json(name: str, **fields):
    """Machine-readable benchmark row: one `BENCH {...}` JSON line per
    measurement so external tooling can track the perf trajectory across PRs
    without parsing the human CSV. Rows are also collected for
    ``write_bench_artifacts``."""
    import json

    row = {"bench": name}
    row.update(fields)
    _BENCH_ROWS.append(row)
    print("BENCH " + json.dumps(row, sort_keys=True))


def write_bench_artifacts(outdir: str = ".") -> list:
    """Write every collected BENCH row to ``BENCH_<name>.json`` (one JSON
    array per bench name, in ``outdir``) and return the written paths. This
    is what makes the perf trajectory durable: the stdout rows vanish with
    the CI log, the artifacts get uploaded (.github/workflows/tier1.yml)."""
    import collections
    import json
    import pathlib

    groups: dict[str, list] = collections.defaultdict(list)
    for row in _BENCH_ROWS:
        groups[row["bench"]].append(row)
    paths = []
    for name, rows in sorted(groups.items()):
        path = pathlib.Path(outdir) / f"BENCH_{name}.json"
        path.write_text(json.dumps(rows, indent=1, sort_keys=True) + "\n")
        paths.append(path)
    return paths
