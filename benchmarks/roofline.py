"""Roofline table: aggregates the dry-run JSON artifacts into the
EXPERIMENTS.md §Roofline table (per arch x shape x mesh: three terms,
bottleneck, MODEL_FLOPS ratio)."""
from __future__ import annotations

import json
import pathlib

from repro.configs import SHAPES, get_config

from .common import emit

DRYRUN_DIR = pathlib.Path("experiments/dryrun")


def model_flops(arch: str, shape: str, n_chips: int) -> float:
    """Useful FLOPs per device per step: 6*N*D for training (N = active
    params, D = tokens), 2*N per token for inference."""
    cfg = get_config(arch)
    seq, gb, kind = SHAPES[shape]
    n = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n * seq * gb / n_chips
    if kind == "prefill":
        return 2.0 * n * seq * gb / n_chips
    return 2.0 * n * gb / n_chips  # decode: one token per sequence


OPTIMIZED_DIR = pathlib.Path("experiments/optimized")


def _emit_dir(directory: pathlib.Path, prefix: str, emit_rows: bool):
    rows = []
    for f in sorted(directory.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("skipped") or "error" in d:
            continue
        rf = d["roofline"]
        mf = model_flops(d["arch"], d["shape"], d["n_chips"])
        useful_ratio = mf / max(d["cost"]["flops_per_device"], 1.0)
        bound = rf["bottleneck"]
        step_s = rf["step_s_lower_bound"]
        frac = mf / 197e12 / max(step_s, 1e-12)  # useful-compute roofline frac
        row = dict(arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
                   style=d.get("style", "tp"),
                   compute_s=rf["compute_s"], memory_s=rf["memory_s"],
                   collective_s=rf["collective_s"], bottleneck=bound,
                   useful_flops_ratio=useful_ratio, roofline_fraction=frac,
                   peak_gib=d["memory"]["peak_bytes"] / 2 ** 30,
                   fits_hbm=d.get("analytic_memory", {}).get("fits_hbm"))
        rows.append(row)
        if emit_rows:
            emit(f"{prefix}/{d['arch']}/{d['shape']}/{d['mesh']}", 0,
                 f"c={rf['compute_s']*1e3:.1f}ms;m={rf['memory_s']*1e3:.1f}ms;"
                 f"x={rf['collective_s']*1e3:.1f}ms;{bound};"
                 f"mfu_frac={frac:.3f};useful={useful_ratio:.2f};"
                 f"fits={row['fits_hbm']}")
    if emit_rows and rows:
        n_bound = {}
        for r in rows:
            n_bound[r["bottleneck"]] = n_bound.get(r["bottleneck"], 0) + 1
        emit(f"{prefix}/cells", 0, str(len(rows)))
        emit(f"{prefix}/bottleneck_histogram", 0,
             ";".join(f"{k}={v}" for k, v in sorted(n_bound.items())))
        emit(f"{prefix}/median_mfu_frac", 0,
             f"{sorted(r['roofline_fraction'] for r in rows)[len(rows)//2]:.3f}")
    return rows


def roofline_table(emit_rows: bool = True):
    rows = _emit_dir(DRYRUN_DIR, "roofline", emit_rows)
    if OPTIMIZED_DIR.exists():
        rows += _emit_dir(OPTIMIZED_DIR, "roofline_optimized", emit_rows)
    return rows
