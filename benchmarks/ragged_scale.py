"""Ragged fleets: one padded program vs per-shape sub-fleets.

Before ragged support, a mixed-shape slice population had to run as one
compiled fleet PER distinct (N, M) — one program, one dispatch and one
sequential device occupancy per shape group. `FleetEngine.from_ragged_configs`
pads everything to the elementwise-max shape and runs ONE vmapped program.
The padding is wasted FLOPs, so this benchmark records the actual trade:
wall time of the padded fleet vs the summed per-shape sub-fleets at
testbed-like scales.

Measured on CPU the padded fleet lands at ~0.8-1.0x of the sub-fleets
(padding waste roughly cancels the cross-group batching win, since each
sub-fleet already batches internally); the structural benefits are 1 compiled
program instead of n_shapes (compile time, program cache) and a single K axis
to shard over a device mesh — per-shape sub-fleets serialise on one mesh.
The `BENCH {...}` JSON rows (see ``common.emit_json``) track both sides so
the trajectory is visible as kernels/pad-shape clustering improve.
"""
from __future__ import annotations

import time

import jax

from repro.core import DS, CocktailConfig, FleetEngine

from .common import emit, emit_json


def _mixed_configs(per_shape: int) -> list[CocktailConfig]:
    """A mixed regional population: small/medium/large slices, shared
    pair_iters (required for ragged batching), heterogeneous params. Shapes
    are testbed-scale, where per-slot cost is dispatch-dominated (the PR 1
    sublinear-batching regime) and padding waste is moderate."""
    shapes = [(4, 2), (6, 3), (8, 3)]
    cfgs = []
    for si, (n, m) in enumerate(shapes):
        for s in range(per_shape):
            cfgs.append(CocktailConfig(
                n_cu=n, n_ec=m, pair_iters=20, seed=10 * si + s,
                zeta=400.0 + 60.0 * ((si + s) % 5),
                eps=0.1 + 0.02 * (s % 3),
                f_base=tuple(8000.0 + 4000.0 * ((s + j) % 4) for j in range(m)),
                c_base=50.0 + 25.0 * ((si + s) % 4),
            ))
    return cfgs


def _timed_run(engines, slots: int, repeat: int) -> float:
    """Mean wall seconds to run all engines for `slots` (compile excluded)."""
    states = [eng.init() for eng in engines]
    outs = [eng.run(slots, st) for eng, st in zip(engines, states)]  # warmup
    for st, _ in outs:
        jax.block_until_ready(st.queues.q)
    t0 = time.perf_counter()
    for _ in range(repeat):
        outs = [eng.run(slots, st) for eng, st in zip(engines, states)]
        for st, _ in outs:
            jax.block_until_ready(st.queues.q)
    return (time.perf_counter() - t0) / repeat


def ragged_scale(per_shape_counts=(1, 2, 4), slots: int = 8, repeat: int = 3):
    rows = {}
    for per_shape in per_shape_counts:
        cfgs = _mixed_configs(per_shape)
        padded = FleetEngine.from_ragged_configs(cfgs, DS)

        groups: dict = {}
        for c in cfgs:
            groups.setdefault(c.shape, []).append(c)
        subfleets = [FleetEngine.from_configs(g, DS) for g in groups.values()]

        dt_pad = _timed_run([padded], slots, repeat)
        dt_sub = _timed_run(subfleets, slots, repeat)

        k = len(cfgs)
        us_pad = dt_pad / slots * 1e6
        us_sub = dt_sub / slots * 1e6
        rows[k] = (us_pad, us_sub)
        emit(f"ragged_scale/K{k}pad{padded.shape.n_cu}x{padded.shape.n_ec}",
             us_pad, f"subfleets {us_sub:.0f}us")
        emit_json("ragged_scale", k=k, n_shapes=len(groups),
                  pad_n_cu=padded.shape.n_cu, pad_n_ec=padded.shape.n_ec,
                  us_per_slot_padded=round(us_pad, 1),
                  us_per_slot_subfleets=round(us_sub, 1),
                  padded_speedup=round(us_sub / us_pad, 3))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    ragged_scale()
