"""Paper-figure benchmarks (Sec. IV): one function per table/figure.

Validation targets from the paper:
  Fig. 5  DS collection STDEV far below NO-SDC / NO-SLT / NO-LSA
          (paper testbed: 308 vs 914 / 1044 / 1433)
  Fig. 6  DS per-EC training STDEV below ablations; NO-LSA worst skew
  Fig. 7  DS accuracy above ablations on the traffic task
  Fig. 8  cost up / backlog down as eps grows; L-DS: lower backlog + more
          data trained + slightly worse skew than DS at the same eps
  Fig. 9  DS unit cost below ECFull / ECSelf / CUFull (paper: up to 43.7%
          reduction vs CUFull); Greedy ~= exact
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ALL_SPECS, CU_FULL, DS, EC_FULL, EC_SELF, GREEDY,
                        LDS, NO_LSA, NO_SDC, NO_SLT, run)
from repro.core import metrics as M

from .common import emit, sim_config, testbed_config

SLOTS = 60


def fig5_collection_evenness():
    cfg = testbed_config()
    vals = {}
    for spec in [DS, NO_SDC, NO_SLT, NO_LSA]:
        t0 = time.perf_counter()
        st, _ = run(cfg, spec, SLOTS)
        us = (time.perf_counter() - t0) * 1e6 / SLOTS
        vals[spec.name] = M.stdev_collection(st)
        emit(f"fig5/stdev_collection/{spec.name}", us, f"{vals[spec.name]:.1f}")
    ok = all(vals["ds"] < vals[k] for k in ("no-sdc", "no-slt", "no-lsa"))
    emit("fig5/ds_most_even", 0, str(ok).lower())
    return vals


def fig6_training_evenness():
    cfg = testbed_config()
    vals = {}
    for spec in [DS, NO_SDC, NO_SLT, NO_LSA]:
        t0 = time.perf_counter()
        st, _ = run(cfg, spec, SLOTS)
        us = (time.perf_counter() - t0) * 1e6 / SLOTS
        stdev = M.stdev_training_per_ec(st)
        vals[spec.name] = stdev
        emit(f"fig6/stdev_training/{spec.name}", us,
             ";".join(f"{v:.0f}" for v in stdev))
    emit("fig6/ds_mean_below_ablations", 0,
         str(bool(np.mean(vals["ds"]) <= min(np.mean(vals[k]) for k in
                                             ("no-sdc", "no-lsa")))).lower())
    return vals


def fig8_ds_vs_lds():
    out = {}
    for eps in (0.1, 0.4):
        for spec in (DS, LDS):
            cfg = testbed_config(eps=eps)
            t0 = time.perf_counter()
            st, _ = run(cfg, spec, SLOTS)
            us = (time.perf_counter() - t0) * 1e6 / SLOTS
            s = M.summary(cfg, st)
            key = f"{spec.name}@eps={eps}"
            out[key] = s
            emit(f"fig8/{key}", us,
                 f"cost={s['avg_cost']:.0f};trained={s['total_trained']:.0f};"
                 f"Q={s['q_backlog']:.0f};R={s['r_backlog']:.0f};"
                 f"skew={s['skew_degree']:.4f}")
    checks = [
        out["ds@eps=0.4"]["q_backlog"] < out["ds@eps=0.1"]["q_backlog"],  # O(1/eps)
        out["l-ds@eps=0.1"]["q_backlog"] < out["ds@eps=0.1"]["q_backlog"],
        out["l-ds@eps=0.1"]["total_trained"] > out["ds@eps=0.1"]["total_trained"],
    ]
    emit("fig8/theory_checks", 0, f"{sum(checks)}/3")
    return out


def fig9_unit_cost():
    rows = {}
    specs = [DS, EC_FULL, EC_SELF, CU_FULL]
    for n_ec in (3, 5, 8):
        cfg = sim_config(n_cu=20, n_ec=n_ec)
        for spec in specs:
            t0 = time.perf_counter()
            st, _ = run(cfg, spec, SLOTS)
            us = (time.perf_counter() - t0) * 1e6 / SLOTS
            uc = M.unit_cost(st)
            rows[(n_ec, spec.name)] = uc
            emit(f"fig9/unit_cost/ec{n_ec}/{spec.name}", us, f"{uc:.2f}")
    for n_cu in (10, 40):
        cfg = sim_config(n_cu=n_cu, n_ec=5)
        for spec in specs:
            t0 = time.perf_counter()
            st, _ = run(cfg, spec, SLOTS)
            us = (time.perf_counter() - t0) * 1e6 / SLOTS
            uc = M.unit_cost(st)
            rows[(f"cu{n_cu}", spec.name)] = uc
            emit(f"fig9/unit_cost/cu{n_cu}/{spec.name}", us, f"{uc:.2f}")
    # headline: max reduction vs CUFull across sweeps
    reds = []
    for key in set(k[0] for k in rows):
        ds = rows[(key, "ds")]
        cf = rows[(key, "cufull")]
        reds.append(100 * (cf - ds) / cf)
    emit("fig9/max_cost_reduction_vs_cufull_pct", 0, f"{max(reds):.1f}")
    return rows
