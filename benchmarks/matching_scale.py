"""Matching-kernel scaling: kernel-vs-reference slot cost for the three
greedy matchers across N x M (paper Sec. III-D scalability table).

Sweeps N in {8, 32, 128, 512} x M in {3, 8, 16}: the testbed shape, the
simulation scale and the "thousands of CUs" regime the kernel subsystem
targets. Per shape it times the jitted jnp references for the two *new*
dispatch ops (skew-aware collection, Thm.-2 pairing) plus the plain
assignment, and — on TPU — the Pallas kernels, emitting one BENCH JSON row
per (op, shape, impl). On CPU the kernels only run in interpret mode (a
Python-level emulator whose timing is meaningless), so instead of timing
them the small shapes get a bit-exactness parity bit in the row.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.matching import ops

from .common import emit, emit_json

N_SWEEP = (8, 32, 128, 512)
M_SWEEP = (3, 8, 16)
# Interpret mode walks the full sequential grid in Python; keep parity checks
# to shapes where that costs < ~1s.
PARITY_MAX_N = 32


def _time(fn, *args, repeat: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat * 1e6  # us


def matching_scale():
    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(0)
    for n in N_SWEEP:
        for m in M_SWEEP:
            logw = jnp.asarray(np.log(rng.uniform(0.2, 40.0, (n, m))), jnp.float32)
            w = jnp.asarray(rng.uniform(-1.0, 10.0, (n, m)), jnp.float32)
            solo = jnp.asarray(rng.uniform(0.0, 5.0, (m,)), jnp.float32)
            pair = rng.uniform(0.0, 10.0, (m, m))
            pair = jnp.asarray((pair + pair.T) / 2, jnp.float32)

            cases = {
                "collection": (lambda a: ops.greedy_collection(a, impl="ref")[0], logw),
                "pairing": (lambda a: ops.greedy_pairing(solo, a, impl="ref"), pair),
                "assignment": (lambda a: ops.greedy_assignment(a, impl="ref"), w),
            }
            for op, (ref_fn, arg) in cases.items():
                us_ref = _time(jax.jit(ref_fn), arg)
                row = dict(op=op, n_cu=n, n_ec=m, us_ref=round(us_ref, 1),
                           backend=jax.default_backend())
                if on_tpu:
                    pallas_fn = {
                        "collection": lambda a: ops.greedy_collection(a, impl="pallas")[0],
                        "pairing": lambda a: ops.greedy_pairing(solo, a, impl="pallas"),
                        "assignment": lambda a: ops.greedy_assignment(a, impl="pallas"),
                    }[op]
                    us_pal = _time(jax.jit(pallas_fn), arg)
                    row["us_pallas"] = round(us_pal, 1)
                    row["speedup"] = round(us_ref / max(us_pal, 1e-9), 2)
                elif n <= PARITY_MAX_N:
                    interp_fn = {
                        "collection": lambda a: ops.greedy_collection(
                            a, impl="pallas", interpret=True)[0],
                        "pairing": lambda a: ops.greedy_pairing(
                            solo, a, impl="pallas", interpret=True),
                        "assignment": lambda a: ops.greedy_assignment(
                            a, impl="pallas", interpret=True),
                    }[op]
                    row["interpret_matches"] = bool(
                        jnp.array_equal(interp_fn(arg), ref_fn(arg)))
                emit(f"matching_scale/{op}/N{n}xM{m}", row["us_ref"],
                     f"ref-{row['backend']}")
                emit_json("matching_scale", **row)


if __name__ == "__main__":
    matching_scale()
