"""Fig. 7: trained-model accuracy under DS vs skew ablations.

The paper's testbed task: cellular-traffic prediction (4 consecutive records
-> next record), one model trained across 3 ECs on data scheduled by each
algorithm; accuracy = fraction of predictions within 15% of the target.
Each CU's traffic distribution differs (non-IID), so a skewed trained set
hurts held-out accuracy across ALL communities — the effect Fig. 7 shows.

Model: small MLP regressor (the paper used an LSTM; the scheduling effect,
not the architecture, is under test — noted in DESIGN.md).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DS, NO_LSA, NO_SDC, NO_SLT, init_state, step
from repro.data import TrafficSource

from .common import emit, testbed_config

SLOTS = 40
HIDDEN = 64


def _mlp_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (4, HIDDEN)) * 0.3,
        "b1": jnp.zeros(HIDDEN),
        "w2": jax.random.normal(k2, (HIDDEN, HIDDEN)) * 0.08,
        "b2": jnp.zeros(HIDDEN),
        "w3": jax.random.normal(k3, (HIDDEN, 1)) * 0.08,
        "b3": jnp.zeros(1),
    }


def _mlp(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    h = jnp.tanh(h @ params["w2"] + params["b2"])
    return (h @ params["w3"] + params["b3"])[:, 0]


@jax.jit
def _train_batch(params, x, y, w, lr=0.02):
    def loss(p):
        pred = _mlp(p, x)
        return jnp.sum(w * (pred - y) ** 2) / jnp.maximum(jnp.sum(w), 1e-9)

    l, g = jax.value_and_grad(loss)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(gg)) for gg in jax.tree.leaves(g)))
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-9))  # clip
    params = jax.tree.map(lambda p, gg: p - lr * scale * gg, params, g)
    return params, l


def _accuracy(params, xs, ys):
    pred = np.asarray(_mlp(params, jnp.asarray(xs)))
    rel = np.abs(pred - ys) / np.maximum(np.abs(ys), 1e-3)
    return float((rel <= 0.15).mean())


def fig7_accuracy():
    cfg = testbed_config()
    sources = [TrafficSource(i, seed=7) for i in range(cfg.n_cu)]
    held = [s.sample(400) for s in sources]  # per-CU held-out sets
    xs_all = np.concatenate([h[0] for h in held])
    ys_all = np.concatenate([h[1] for h in held])

    results = {}
    for spec in [DS, NO_SDC, NO_SLT, NO_LSA]:
        params = _mlp_init(jax.random.PRNGKey(0))
        st = init_state(cfg)
        t0 = time.perf_counter()
        accs = []
        n_draw = None
        for t in range(SLOTS):
            st, rec, dec = step(cfg, spec, st)
            trained = np.asarray(dec.x) + np.asarray(dec.y).sum(axis=1)  # (N, M)
            per_cu = trained.sum(axis=1)
            total = per_cu.sum()
            if total > 0:  # else: keep training the previous composition
                n_draw = np.maximum((per_cu / total * 256).astype(int), 0)
            if n_draw is not None and n_draw.sum() > 0:
                xs, ys, ws = [], [], []
                for i, n in enumerate(n_draw):
                    if n == 0:
                        continue
                    x, y = sources[i].sample(int(n))
                    xs.append(x)
                    ys.append(y)
                    ws.extend([1.0] * int(n))
                xj = jnp.asarray(np.concatenate(xs))
                yj = jnp.asarray(np.concatenate(ys))
                wj = jnp.asarray(ws, jnp.float32)
                for _ in range(4):  # a few optimizer steps per slot
                    params, _ = _train_batch(params, xj, yj, wj)
            if (t + 1) % 10 == 0:
                accs.append(_accuracy(params, xs_all, ys_all))
        us = (time.perf_counter() - t0) * 1e6 / SLOTS
        results[spec.name] = accs
        emit(f"fig7/accuracy/{spec.name}", us,
             ";".join(f"{a:.3f}" for a in accs))
    final = {k: v[-1] for k, v in results.items()}
    emit("fig7/ds_at_least_competitive", 0,
         str(final["ds"] >= max(v for k, v in final.items() if k != "ds") - 0.05).lower())
    return results
