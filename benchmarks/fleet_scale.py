"""Fleet scalability: per-slot wall time of one jitted K-slice program vs K.

The batch-first refactor's headline claim is that K heterogeneous slices cost
ONE compiled program whose per-slot time grows sublinearly in K (vmap turns
the K-way Python loop into batched kernels). This benchmark sweeps K at a
fixed slice shape, reports slices x slots/sec and per-slot microseconds, and
emits `BENCH {...}` JSON rows (see ``common.emit_json``) so the perf
trajectory starts recording. The single-slice (N, M) sweep lives in
``sched_scale``; this is its fleet-axis counterpart.
"""
from __future__ import annotations

import time

import jax

from repro.core import DS, CocktailConfig, FleetEngine

from .common import emit, emit_json


def _heterogeneous_configs(k: int, n_cu: int, n_ec: int) -> list[CocktailConfig]:
    """K slices sharing one shape but with per-slice rates/costs/budgets."""
    cfgs = []
    for s in range(k):
        cfgs.append(CocktailConfig(
            n_cu=n_cu, n_ec=n_ec, pair_iters=20, seed=s,
            zeta=400.0 + 50.0 * (s % 5),
            eps=0.1 + 0.02 * (s % 3),
            f_base=tuple(8000.0 + 4000.0 * ((s + j) % 4) for j in range(n_ec)),
            c_base=50.0 + 25.0 * (s % 4),
        ))
    return cfgs


def fleet_scale(ks=(1, 2, 4, 8, 16), n_cu: int = 8, n_ec: int = 3,
                slots: int = 8, repeat: int = 3):
    """Default shape is the paper-testbed scale, where per-slot cost is
    dispatch-overhead dominated and batching K slices is strongly sublinear
    (~10x wall for K=16 on CPU). Large shapes (N=32, M=8) are compute-bound
    and scale ~linearly in K on CPU — there the win is devices: shard the K
    axis over a mesh (FleetEngine.run(mesh=...))."""
    rows = {}
    base_us = None
    for k in ks:
        eng = FleetEngine.from_configs(_heterogeneous_configs(k, n_cu, n_ec), DS)
        state = eng.init()
        st, _ = eng.run(slots, state)  # compile + warmup
        jax.block_until_ready(st.queues.q)
        t0 = time.perf_counter()
        for _ in range(repeat):
            st, _ = eng.run(slots, state)
        jax.block_until_ready(st.queues.q)
        dt = (time.perf_counter() - t0) / repeat
        us_per_slot = dt / slots * 1e6
        slice_slots_per_sec = k * slots / dt
        if base_us is None:
            base_us = us_per_slot
        rows[k] = us_per_slot
        emit(f"fleet_scale/K{k}xN{n_cu}xM{n_ec}", us_per_slot,
             f"{slice_slots_per_sec:.0f} slice-slots/s")
        emit_json("fleet_scale", k=k, n_cu=n_cu, n_ec=n_ec,
                  us_per_slot=round(us_per_slot, 1),
                  us_per_slot_per_slice=round(us_per_slot / k, 1),
                  slice_slots_per_sec=round(slice_slots_per_sec, 1),
                  base_k=ks[0],
                  scaling_vs_base=round(us_per_slot / base_us, 3))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    fleet_scale()
