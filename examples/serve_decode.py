"""Batched decode serving example: KV-cache generation on a reduced config
of any assigned architecture (ring-buffer caches for SWA archs, recurrent
state for SSM archs).

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve.main(["--arch", args.arch, "--reduced", "--batch", str(args.batch),
                "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
