"""End-to-end driver: train an LM under Cocktail-scheduled non-IID data.

Default config is CPU-sized (~20M params, 120 steps) so the example runs in
minutes; the ~100M-parameter run the deliverable describes is the same
command with bigger flags (a few hours on CPU, minutes on one TPU host):

    PYTHONPATH=src python examples/train_lm_cocktail.py \
        --d-model 640 --layers 10 --vocab 50048 --steps 300 --batch 16

The driver demonstrates: scheduler-driven batch composition + |D_j| sample
weighting (paper eq. 15), heterogeneous-EC straggler handling, checkpoint /
auto-resume (kill it mid-run and re-run the same command).
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=320)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/cocktail_lm_ckpt")
    args = ap.parse_args()

    # register a custom-size dense config (minitron family, scaled)
    import repro.configs.base as base
    cfg = dataclasses.replace(
        get_config("minitron-4b"),
        name="lm-example",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 2), n_kv_heads=max(args.d_model // 128, 1),
        head_dim=64, d_ff=args.d_model * 3, vocab_size=args.vocab,
        head_pad_multiple=1, remat=False,
        param_dtype="float32", compute_dtype="float32",
    )
    base.register(cfg)
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    summary = train_mod.main([
        "--arch", "lm-example", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--checkpoint-dir", args.checkpoint_dir,
        "--scheduler", "ds",
    ])
    assert summary["last_loss"] < summary["first_loss"], "loss must decrease"
    print(f"loss {summary['first_loss']:.3f} -> {summary['last_loss']:.3f} OK")


if __name__ == "__main__":
    main()
