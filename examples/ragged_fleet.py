"""Ragged fleet: mixed-shape slices batched in one compiled program.

A real operator's slice population is shape-heterogeneous: a rural region
with a handful of CUs and two ECs schedules next to a metro slice with
dozens of CUs and a fat EC pool. `FleetEngine.from_jobs` pads every
slice to the elementwise-max shape, and the `cu_mask`/`ec_mask` entity masks
in `SliceParams` guarantee the padding is inert — each slice's schedule is
the same as if it ran alone, unpadded (tests/test_ragged_fleet.py asserts it
bit-exactly for the single-slice path).

    PYTHONPATH=src python examples/ragged_fleet.py
"""
import os

from repro.core import DS, CocktailConfig, FleetEngine, SliceJob
from repro.core import metrics

SLOTS = int(os.environ.get("COCKTAIL_EXAMPLE_SLOTS", "60"))

# Small rural slice: paper-testbed scale, 6 CUs on 3 modest ECs.
rural = CocktailConfig(
    n_cu=6, n_ec=3, delta=0.02, eps=0.1, zeta=400.0,
    d_base=2000.0, cap_d_base=8000.0, f_base=(8000.0, 20000.0, 8000.0),
    c_base=50.0, e_base=50.0, p_base=200.0, pair_iters=30, seed=0,
)

# Large metro slice: 16 CUs, 5 ECs, heavier arrivals and fatter compute.
metro = CocktailConfig(
    n_cu=16, n_ec=5, delta=0.03, eps=0.15, zeta=900.0,
    d_base=2500.0, cap_d_base=10000.0,
    f_base=(48000.0, 32000.0, 20000.0, 20000.0, 14000.0),
    c_base=60.0, e_base=40.0, p_base=150.0, pair_iters=30, seed=1,
)

# Mid-size suburban slice riding along.
suburb = CocktailConfig(
    n_cu=10, n_ec=4, delta=0.02, eps=0.1, zeta=600.0,
    d_base=2000.0, cap_d_base=8000.0,
    f_base=(8000.0, 14000.0, 20000.0, 14000.0),
    c_base=50.0, e_base=50.0, p_base=180.0, pair_iters=30, seed=2,
)

jobs = [SliceJob(rural, DS, name="rural/6x3"),
        SliceJob(metro, DS, name="metro/16x5"),
        SliceJob(suburb, DS, name="suburb/10x4")]

engine = FleetEngine.from_jobs(jobs)
print(f"ragged fleet: {engine.n_slices} slices x {SLOTS} slots, padded to "
      f"N={engine.shape.n_cu} M={engine.shape.n_ec} — one jitted scan")
print("true shapes:", ", ".join(f"{j.config.n_cu}x{j.config.n_ec}" for j in jobs), "\n")

state, recs = engine.run(SLOTS)

print(f"{'slice':12s} {'unit_cost':>9s} {'trained':>10s} {'skew':>7s} {'q_backlog':>10s}")
for k, job in enumerate(jobs):
    # slice_state trims the padding, so metrics work off the original config
    s = metrics.summary(job.config, engine.slice_state(state, k))
    print(f"{job.name:12s} {s['unit_cost']:9.2f} {s['total_trained']:10.0f} "
          f"{s['skew_degree']:7.4f} {s['q_backlog']:10.0f}")

print("\nper-slot fleet records are time-major (T, K):", tuple(recs.cost.shape))
