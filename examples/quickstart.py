"""Quickstart: run the Cocktail scheduler for 60 slots on the paper's
testbed topology (6 CUs / 3 heterogeneous ECs) and compare DataSche with the
CU-full-connection strawman.

    PYTHONPATH=src python examples/quickstart.py
"""
import json
import os

from repro.core import CU_FULL, DS, LDS, CocktailConfig, run
from repro.core import metrics

cfg = CocktailConfig(
    n_cu=6, n_ec=3, delta=0.02, eps=0.1,
    f_base=(8000.0, 20000.0, 8000.0),  # one fast EC, two slow (paper testbed)
    c_base=250.0, e_base=50.0, p_base=200.0, pair_iters=30, seed=0,
)

SLOTS = int(os.environ.get("COCKTAIL_EXAMPLE_SLOTS", "60"))

print(f"slot-by-slot online scheduling, {SLOTS} slots "
      f"(~{SLOTS * 5 / 60:.1f}h of 5-min slots)\n")
for spec in (DS, LDS, CU_FULL):
    state, recs = run(cfg, spec, SLOTS)
    s = metrics.summary(cfg, state)
    print(f"{spec.name:8s} unit_cost={s['unit_cost']:8.2f} "
          f"trained={s['total_trained']:9.0f} samples  "
          f"skew_degree={s['skew_degree']:.4f}  "
          f"collection_stdev={s['stdev_collection']:7.1f}")

state, _ = run(cfg, DS, SLOTS)
cf, _ = run(cfg, CU_FULL, SLOTS)
red = 100 * (metrics.unit_cost(cf) - metrics.unit_cost(state)) / metrics.unit_cost(cf)
print(f"\nDataSche cost reduction vs CUFull: {red:.1f}% "
      "(paper reports up to 43.7% across scenarios)")
print(json.dumps(metrics.summary(cfg, state), indent=2))
