"""Fleet scheduling: one compiled program driving many network slices.

A 5G operator runs heterogeneous incremental-learning jobs concurrently:
regional traffic-prediction slices (modest arrival rates, cheap transmission,
testbed-like EC budgets) next to tenant LM-training slices (heavy arrivals,
pricier compute, fat ECs). With the batch-first core these are ONE
``FleetEngine``: each slice is a ``SliceJob`` (config + algorithm + seed),
``from_jobs`` stacks them into one ``SliceParams`` pytree, and every slot is
a single vmapped step inside one jitted scan.

    PYTHONPATH=src python examples/fleet_multi_slice.py
"""
import dataclasses
import os

from repro.core import DS, CocktailConfig, FleetEngine, SliceJob
from repro.core import metrics

N_CU, N_EC = 12, 4
SLOTS = int(os.environ.get("COCKTAIL_EXAMPLE_SLOTS", "60"))

# Profile A: regional traffic prediction (paper testbed scaled up) ---------
traffic = CocktailConfig(
    n_cu=N_CU, n_ec=N_EC, delta=0.02, eps=0.1, zeta=500.0,
    d_base=2000.0, cap_d_base=8000.0,
    f_base=(8000.0, 20000.0, 8000.0, 14000.0),
    c_base=50.0, e_base=50.0, p_base=200.0, pair_iters=30, seed=0,
)

# Profile B: tenant LM training — heavier arrivals, fatter ECs, pricier
# compute, looser skew tolerance.
lm = dataclasses.replace(
    traffic, zeta=1200.0, delta=0.05, eps=0.15,
    f_base=(48000.0, 48000.0, 20000.0, 20000.0),
    c_base=80.0, p_base=120.0, seed=1,
)

jobs = [
    SliceJob(traffic, DS, name="traffic/region-0"),
    SliceJob(dataclasses.replace(traffic, zeta=350.0, seed=2), DS,
             name="traffic/region-1"),
    SliceJob(dataclasses.replace(traffic, zeta=800.0, seed=3), DS,
             name="traffic/region-2"),
    SliceJob(lm, DS, name="lm/tenant-a"),
    SliceJob(dataclasses.replace(lm, zeta=900.0, eps=0.2, seed=4), DS,
             name="lm/tenant-b"),
]

engine = FleetEngine.from_jobs(jobs)
print(f"fleet: {engine.n_slices} slices x {SLOTS} slots, shape "
      f"N={engine.shape.n_cu} M={engine.shape.n_ec} — one jitted scan\n")

state, recs = engine.run(SLOTS)

print(f"{'slice':18s} {'unit_cost':>9s} {'trained':>10s} {'skew':>7s} {'q_backlog':>10s}")
for k, job in enumerate(jobs):
    s = metrics.summary(job.config, engine.slice_state(state, k))
    print(f"{job.name:18s} {s['unit_cost']:9.2f} {s['total_trained']:10.0f} "
          f"{s['skew_degree']:7.4f} {s['q_backlog']:10.0f}")

print("\nper-slot fleet cost (records are time-major (T, K)):",
      tuple(recs.cost.shape))
