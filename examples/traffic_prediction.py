"""The paper's own testbed scenario end-to-end (Sec. IV-A/B): cooperative
traffic-prediction training across 3 edge clouds fed by 6 CUs, scheduled by
DataSche; compares final model accuracy (within-15% criterion) under DS and
the NO-LSA ablation.

    PYTHONPATH=src python examples/traffic_prediction.py
"""
import sys

sys.path.insert(0, "src")

from benchmarks import fig7_accuracy


def main():
    print("training traffic predictors under 4 scheduling policies "
          "(paper Fig. 7 reproduction)...")
    print("name,us_per_call,derived")
    results = fig7_accuracy.fig7_accuracy()
    print()
    for name, accs in results.items():
        print(f"{name:8s} accuracy over slots: "
              + " -> ".join(f"{a:.1%}" for a in accs))


if __name__ == "__main__":
    main()
