"""Mixed-policy fleet: per-slice algorithms, one compiled program.

The normal state of a staged rollout: most slices run the full skew-aware
DataSche in production, while a few canaries run ablated baselines (plain
greedy collection, LSA off) to keep a live regression reference. Before the
SliceJob frontend this cost one compiled program PER AlgoSpec; with
branch-free dispatch the policy choice is data (`lax.switch` over the indexed
policy tables, driven by the `SliceParams` policy leaves), so the whole
heterogeneous fleet — mixed algorithms AND mixed shapes — is ONE vmapped,
jitted scan. Each slice still reproduces its standalone single-spec `run()`
(tests/test_policy_switch.py).

    PYTHONPATH=src python examples/mixed_policy_fleet.py
"""
import dataclasses
import os

from repro.core import DS, NO_LSA, NO_SDC, CocktailConfig, FleetEngine, SliceJob
from repro.core import metrics

SLOTS = int(os.environ.get("COCKTAIL_EXAMPLE_SLOTS", "60"))

# Production profile: paper-testbed-like regional slice under full DataSche.
prod = CocktailConfig(
    n_cu=8, n_ec=3, delta=0.02, eps=0.1, zeta=500.0,
    d_base=2000.0, cap_d_base=8000.0, f_base=(8000.0, 20000.0, 12000.0),
    c_base=50.0, e_base=50.0, p_base=200.0, pair_iters=30, seed=0,
)

# Canary profile: smaller slice (ragged — from_jobs pads it), used to A/B the
# ablated baselines against production on live traffic.
canary = dataclasses.replace(prod, n_cu=6, f_base=(8000.0, 20000.0, 8000.0))

jobs = [
    SliceJob(prod, DS, name="prod/region-0"),
    SliceJob(dataclasses.replace(prod, zeta=700.0, seed=1), DS,
             name="prod/region-1"),
    SliceJob(dataclasses.replace(prod, zeta=350.0, seed=2), DS,
             name="prod/region-2"),
    SliceJob(dataclasses.replace(canary, seed=3), NO_SDC, name="canary/no-sdc"),
    SliceJob(dataclasses.replace(canary, seed=4), NO_LSA, name="canary/no-lsa"),
]

engine = FleetEngine.from_jobs(jobs)
print(f"mixed-policy fleet: {engine.n_slices} slices x {SLOTS} slots, "
      f"dispatch={engine.spec.name}, padded to "
      f"N={engine.shape.n_cu} M={engine.shape.n_ec} — one jitted scan")
print("slice specs:", ", ".join(j.spec.name for j in jobs), "\n")

state, recs = engine.run(SLOTS)

print(f"{'slice':16s} {'spec':8s} {'unit_cost':>9s} {'trained':>10s} "
      f"{'skew':>7s} {'q_backlog':>10s}")
for k, job in enumerate(jobs):
    s = metrics.summary(job.config, engine.slice_state(state, k))
    print(f"{job.name:16s} {job.spec.name:8s} {s['unit_cost']:9.2f} "
          f"{s['total_trained']:10.0f} {s['skew_degree']:7.4f} "
          f"{s['q_backlog']:10.0f}")

print("\nper-slot fleet records are time-major (T, K):", tuple(recs.cost.shape))
